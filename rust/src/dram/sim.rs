//! Cycle-level multi-channel DDR5 memory system with an FR-FCFS scheduler
//! and a DRAMSim3-style energy model.
//!
//! Fidelity: bank/bank-group/rank timing (tRCD/tRP/tRAS/tRC, tRRD_S/L,
//! tCCD_S/L, tFAW, CL/CWL, write→read turnaround), open-page policy with
//! FR-FCFS (column hits first, then oldest), periodic all-bank refresh
//! (tREFI/tRFC). One rank per channel, as in the paper's setup.

use super::addrmap::{AddrMap, Address};
use super::bank::{Bank, RankTiming};
use crate::configs::ddr5::Ddr5Config;

/// A burst-granular memory request.
#[derive(Debug, Clone, Copy)]
pub struct Request {
    pub addr: u64,
    pub is_write: bool,
    /// Issue time in cycles (arrival at the controller).
    pub arrival: u64,
    /// Caller tag for correlating completions.
    pub tag: u64,
}

/// A completed request with its finish cycle.
#[derive(Debug, Clone, Copy)]
pub struct Completion {
    pub tag: u64,
    pub finish: u64,
}

#[derive(Debug, Clone, Copy)]
struct Pending {
    addr: Address,
    /// Flat bank index (`bankgroup * banks_per_group + bank`) — the
    /// bucket key, precomputed at enqueue.
    bank: usize,
    is_write: bool,
    arrival: u64,
    tag: u64,
}

/// Arrival-ordered request queue with O(1) removal and a per-bank bucket
/// index for the FR-FCFS hit scan.
///
/// Slots are tombstoned (`None`) instead of shifted (`Vec::remove` was
/// O(n) per FR-FCFS issue, quadratic per drained queue at depth 64+) and
/// addressed by *virtual index*: assigned at push, monotone in age, and
/// stable across front-trimming (`front` tracks the virtual index of
/// `slots[0]`). Each bank's bucket holds its entries' virtual indices
/// oldest-first, so the scheduler's row-hit scan touches only the banks
/// that can issue — O(banks) instead of O(queue) per cycle — while
/// comparing candidates by virtual index preserves exact global FCFS age
/// order. Bucket entries go stale when their slot is removed: stale
/// fronts are popped lazily, stale interiors are skipped by the scan and
/// dropped wholesale when tombstones force a compaction (which rebuilds
/// the buckets; rare by the growth threshold, and never between a scan
/// and its removal). Scheduling order is identical to the old linear
/// scan — property-tested against it below.
struct ReqQueue {
    slots: std::collections::VecDeque<Option<Pending>>,
    live: usize,
    /// Virtual index of `slots[0]`.
    front: u64,
    /// Per-bank FIFO of virtual indices (oldest first, lazily pruned).
    buckets: Vec<std::collections::VecDeque<u64>>,
}

impl ReqQueue {
    fn new(nbanks: usize) -> Self {
        Self {
            slots: std::collections::VecDeque::new(),
            live: 0,
            front: 0,
            buckets: vec![std::collections::VecDeque::new(); nbanks],
        }
    }

    fn len(&self) -> usize {
        self.live
    }

    fn is_empty(&self) -> bool {
        self.live == 0
    }

    fn push(&mut self, p: Pending) {
        let v = self.front + self.slots.len() as u64;
        self.buckets[p.bank].push_back(v);
        self.slots.push_back(Some(p));
        self.live += 1;
    }

    /// Live entries oldest-first, with stable *virtual* indices for
    /// [`ReqQueue::remove`].
    fn iter(&self) -> impl Iterator<Item = (u64, &Pending)> + '_ {
        let front = self.front;
        self.slots
            .iter()
            .enumerate()
            .filter_map(move |(i, s)| s.as_ref().map(|p| (front + i as u64, p)))
    }

    /// Entry by virtual index (None once removed or trimmed).
    fn get(&self, v: u64) -> Option<&Pending> {
        if v < self.front {
            return None;
        }
        self.slots
            .get((v - self.front) as usize)
            .and_then(|s| s.as_ref())
    }

    /// Oldest live entry.
    fn first(&self) -> Option<&Pending> {
        self.iter().next().map(|(_, p)| p)
    }

    /// Oldest live entry in `bank`'s bucket that targets `row`, has
    /// arrived, and satisfies `ready` — the per-bank FR-FCFS hit
    /// candidate. Walks the bucket in age order, so the first match IS
    /// the bank's oldest match; comparing returned virtual indices across
    /// banks reproduces the global age order of the old linear scan.
    fn oldest_hit(
        &mut self,
        bank: usize,
        row: usize,
        cycle: u64,
        ready: impl Fn(&Pending) -> bool,
    ) -> Option<u64> {
        // prune dead fronts so the common case touches only live heads
        while let Some(&v) = self.buckets[bank].front() {
            if self.get(v).is_some() {
                break;
            }
            self.buckets[bank].pop_front();
        }
        for &v in &self.buckets[bank] {
            let Some(p) = self.get(v) else { continue };
            if p.addr.row == row && p.arrival <= cycle && ready(p) {
                return Some(v);
            }
        }
        None
    }

    /// Remove by virtual index (as yielded by [`ReqQueue::iter`] /
    /// [`ReqQueue::oldest_hit`]).
    fn remove(&mut self, v: u64) -> Pending {
        let idx = (v - self.front) as usize;
        let p = self.slots[idx].take().expect("live queue slot");
        self.live -= 1;
        // trim leading tombstones (virtual front advances with them)
        while matches!(self.slots.front(), Some(None)) {
            self.slots.pop_front();
            self.front += 1;
        }
        // compact when tombstones dominate: virtual indices are
        // reassigned, so the buckets rebuild (amortized by the threshold)
        if self.slots.len() > 2 * self.live + 8 {
            self.slots.retain(|s| s.is_some());
            for b in &mut self.buckets {
                b.clear();
            }
            for (i, s) in self.slots.iter().enumerate() {
                if let Some(q) = s {
                    self.buckets[q.bank].push_back(self.front + i as u64);
                }
            }
        }
        p
    }

    /// Live entries of one bank oldest-first (reference-test aid).
    #[cfg(test)]
    fn bank_live(&self, bank: usize) -> Vec<&Pending> {
        self.buckets[bank]
            .iter()
            .filter_map(|&v| self.get(v))
            .collect()
    }
}

/// Energy counters (per channel, aggregated at report time).
#[derive(Debug, Clone, Copy, Default)]
pub struct EnergyCounters {
    pub activates: u64,
    pub reads: u64,
    pub writes: u64,
    pub refreshes: u64,
}

/// Aggregate statistics from a simulation run.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct SimStats {
    pub cycles: u64,
    pub requests: u64,
    pub read_bursts: u64,
    pub write_bursts: u64,
    pub activates: u64,
    pub refreshes: u64,
    pub row_hits: u64,
    pub row_misses: u64,
    pub row_conflicts: u64,
    /// Sum of per-request latencies (cycles from arrival to data).
    pub total_latency: u64,
    /// Requests re-enqueued by the controller's recovery ladder (bounded
    /// retry of injected transient bus/lane faults). Counted in addition
    /// to `requests`; zero on a fault-free run.
    pub retried_requests: u64,
}

impl SimStats {
    pub fn avg_latency_cycles(&self) -> f64 {
        if self.requests == 0 {
            0.0
        } else {
            self.total_latency as f64 / self.requests as f64
        }
    }

    /// Energy in pJ from the counters + config (activation, rd/wr burst,
    /// refresh; background power excluded — the paper's Fig 10 reports
    /// read + activation energy, which we mirror).
    pub fn energy_pj(&self, cfg: &Ddr5Config) -> EnergyBreakdown {
        EnergyBreakdown {
            activation_pj: self.activates as f64 * cfg.act_energy_pj(),
            read_pj: self.read_bursts as f64 * cfg.read_energy_pj(),
            write_pj: self.write_bursts as f64 * cfg.write_energy_pj(),
            refresh_pj: self.refreshes as f64
                * (cfg.vdd * cfg.idd5b * cfg.t_rfc as f64 * cfg.t_ck() * 1e-3 * 1e12)
                * cfg.devices as f64,
        }
    }
}

/// Energy breakdown in pJ.
#[derive(Debug, Clone, Copy, Default)]
pub struct EnergyBreakdown {
    pub activation_pj: f64,
    pub read_pj: f64,
    pub write_pj: f64,
    pub refresh_pj: f64,
}

impl EnergyBreakdown {
    pub fn total_pj(&self) -> f64 {
        self.activation_pj + self.read_pj + self.write_pj + self.refresh_pj
    }
}

/// Analytic DRAM read energy for moving `bytes` when no simulated
/// [`MemorySystem`] counted bursts (the serve loop's latency model): the
/// read bursts the transfer implies plus the row activations they touch,
/// mirroring the read + activation surface the paper's Fig 10 reports
/// (what [`SimStats::energy_pj`] computes from simulated counters).
/// Integer femtojoules, so per-tenant attribution sums conserve
/// bit-exactly and are reproducible across lane counts.
pub fn modeled_read_energy_fj(cfg: &Ddr5Config, bytes: u64) -> u64 {
    if bytes == 0 {
        return 0;
    }
    let read_fj_per_burst = (cfg.read_energy_pj() * 1000.0) as u64;
    let act_fj_per_row = (cfg.act_energy_pj() * 1000.0) as u64;
    let bursts = bytes.div_ceil(cfg.burst_bytes() as u64);
    let rows = bytes.div_ceil(cfg.row_bytes as u64);
    bursts * read_fj_per_burst + rows * act_fj_per_row
}

struct Channel {
    banks: Vec<Bank>, // bankgroups * banks_per_group
    rank: RankTiming,
    queue: ReqQueue,
    next_refresh: u64,
    /// Scan suppression: this channel cannot issue before this cycle
    /// (recomputed after every fruitless scan, cleared on enqueue).
    skip_until: u64,
}

/// The memory system simulator.
pub struct MemorySystem {
    pub cfg: Ddr5Config,
    map: AddrMap,
    channels: Vec<Channel>,
    cycle: u64,
    pub stats: SimStats,
    /// Per-channel split of [`MemorySystem::stats`]: every traffic
    /// counter (requests, bursts, activates, refreshes, row outcomes,
    /// latency, retries) increments the owning channel's entry at the
    /// same site as the aggregate, so the non-`cycles` fields sum
    /// *bit-exactly* to `stats` (unit-tested below). `cycles` is the
    /// system-wide clock — it lives only in the aggregate and stays 0
    /// here. A retry tick is attributed to the channel of the retried
    /// range's base address.
    pub channel_stats: Vec<SimStats>,
    completions: Vec<Completion>,
    /// Max queued requests per channel before `enqueue` reports backpressure.
    pub queue_depth: usize,
    /// When no command can issue, jump straight to the next actionable
    /// event (earliest bank/rank timer, request arrival, or refresh
    /// deadline) instead of ticking idle cycles one by one. Cycle counts
    /// and stats are identical either way (asserted by the equivalence
    /// test); `false` is the slow reference mode.
    pub fast_forward: bool,
}

impl MemorySystem {
    pub fn new(cfg: Ddr5Config) -> Self {
        let map = AddrMap::new(&cfg);
        let channels = (0..cfg.channels)
            .map(|_| Channel {
                banks: (0..cfg.banks()).map(|_| Bank::default()).collect(),
                rank: RankTiming::new(cfg.bankgroups),
                queue: ReqQueue::new(cfg.banks()),
                next_refresh: cfg.t_refi,
                skip_until: 0,
            })
            .collect();
        let n_channels = channels.len();
        Self {
            cfg,
            map,
            channels,
            cycle: 0,
            stats: SimStats::default(),
            channel_stats: vec![SimStats::default(); n_channels],
            completions: Vec::new(),
            queue_depth: 64,
            fast_forward: true,
        }
    }

    pub fn now(&self) -> u64 {
        self.cycle
    }

    /// Enqueue a burst request. Returns false if the channel queue is full
    /// (caller should tick and retry — backpressure).
    pub fn enqueue(&mut self, req: Request) -> bool {
        let addr = self.map.decode(req.addr);
        let ch = &mut self.channels[addr.channel];
        if ch.queue.len() >= self.queue_depth {
            return false;
        }
        ch.queue.push(Pending {
            addr,
            bank: addr.bankgroup * self.cfg.banks_per_group + addr.bank,
            is_write: req.is_write,
            arrival: req.arrival.max(self.cycle),
            tag: req.tag,
        });
        // a fresh request can still not issue before the rank-level floor
        let floor = ch.rank.issue_floor(&self.cfg);
        ch.skip_until = ch.skip_until.min(floor);
        self.stats.requests += 1;
        self.channel_stats[addr.channel].requests += 1;
        true
    }

    /// Enqueue a byte range as a sequence of 64 B bursts. Returns the tags
    /// used ([first, first+n)).
    pub fn enqueue_range(&mut self, base: u64, bytes: u64, is_write: bool, first_tag: u64) -> u64 {
        let burst = self.cfg.burst_bytes() as u64;
        let start = base / burst * burst;
        let end = (base + bytes).div_ceil(burst) * burst;
        let mut tag = first_tag;
        let mut a = start;
        while a < end {
            while !self.enqueue(Request {
                addr: a,
                is_write,
                arrival: self.cycle,
                tag,
            }) {
                self.tick();
            }
            a += burst;
            tag += 1;
        }
        tag
    }

    /// Re-enqueue a byte range the recovery ladder is re-reading after a
    /// transient fault. Identical bus traffic to the original read
    /// ([`enqueue_range`](Self::enqueue_range) with tag 0), plus one
    /// `retried_requests` tick per call so fault-free and faulty runs are
    /// distinguishable in [`SimStats`].
    pub fn enqueue_retry(&mut self, base: u64, bytes: u64) -> u64 {
        self.enqueue_retry_tagged(base, bytes, 0)
    }

    /// [`enqueue_retry`](Self::enqueue_retry) with caller-correlated burst
    /// tags: cycle-interleaved readers
    /// ([`fetch_group`](crate::memctrl::MemController::fetch_group)) tag a
    /// retry's bursts into the frame they re-read, so the frame's modeled
    /// completion time honestly includes the retry traffic. Returns the
    /// next free tag, exactly like [`enqueue_range`](Self::enqueue_range).
    pub fn enqueue_retry_tagged(&mut self, base: u64, bytes: u64, first_tag: u64) -> u64 {
        self.stats.retried_requests += 1;
        let burst = self.cfg.burst_bytes() as u64;
        let ch = self.map.decode(base / burst * burst).channel;
        self.channel_stats[ch].retried_requests += 1;
        self.enqueue_range(base, bytes, false, first_tag)
    }

    /// Drain all queues; returns the cycle when the last data beat lands.
    pub fn drain(&mut self) -> u64 {
        while self.channels.iter().any(|c| !c.queue.is_empty()) {
            self.tick();
        }
        // let in-flight bursts land
        let last_bus: u64 = self
            .channels
            .iter()
            .map(|c| c.rank.bus_free)
            .max()
            .unwrap_or(self.cycle);
        self.cycle = self.cycle.max(last_bus);
        self.stats.cycles = self.cycle;
        self.cycle
    }

    /// Take accumulated completions.
    pub fn take_completions(&mut self) -> Vec<Completion> {
        std::mem::take(&mut self.completions)
    }

    /// Advance one controller cycle: per channel, maybe refresh, then
    /// FR-FCFS pick one command to issue. When no channel can make
    /// progress, jump directly to the next timing-constraint boundary —
    /// exact event skipping (between boundaries the ready set cannot
    /// change), worth ~20× on streaming workloads (§Perf).
    pub fn tick(&mut self) {
        let issued = self.tick_issue();
        if issued || !self.fast_forward {
            self.cycle += 1;
        } else {
            let nxt = self.next_event();
            self.cycle = nxt.max(self.cycle + 1);
        }
    }

    /// Earliest cycle strictly after `self.cycle` at which any timing
    /// constraint boundary occurs (lower bound on the next state change).
    fn next_event(&self) -> u64 {
        let cfg = &self.cfg;
        let mut best = u64::MAX;
        let mut upd = |t: u64| {
            if t > self.cycle && t < best {
                best = t;
            }
        };
        for ch in &self.channels {
            if ch.queue.is_empty() {
                continue;
            }
            if ch.skip_until > self.cycle {
                upd(ch.skip_until);
                continue;
            }
            upd(ch.next_refresh);
            for (_, p) in ch.queue.iter() {
                upd(p.arrival);
                let b = &ch.banks[p.bank];
                upd(b.next_act);
                upd(b.next_pre);
                upd(b.next_rdwr);
                upd(ch.rank.act_ready(cfg, p.addr.bankgroup));
                upd(ch.rank.col_ready(cfg, p.addr.bankgroup, p.is_write));
            }
        }
        if best == u64::MAX {
            self.cycle + 1
        } else {
            best
        }
    }

    /// Issue at most one command per channel at the current cycle.
    /// Returns true if any channel issued a column command or made bank
    /// progress (ACT/PRE) — i.e. the cycle was not idle.
    fn tick_issue(&mut self) -> bool {
        let mut progressed = false;
        let cycle = self.cycle;
        let cfg = &self.cfg;
        let ff = self.fast_forward;
        for (ci, ch) in self.channels.iter_mut().enumerate() {
            // scan suppression is part of the fast path; the naive
            // reference mode rescans every channel every cycle
            if (ff && cycle < ch.skip_until) || ch.queue.is_empty() {
                continue;
            }
            // refresh takes priority (all-bank, blocking)
            if cycle >= ch.next_refresh {
                // wait for banks to be precharged: force-close rows
                for b in ch.banks.iter_mut() {
                    b.open_row = None;
                    let ready = b.next_pre.max(cycle) + cfg.t_rp + cfg.t_rfc;
                    b.next_act = b.next_act.max(ready);
                }
                ch.next_refresh += cfg.t_refi;
                self.stats.refreshes += 1;
                self.channel_stats[ci].refreshes += 1;
                progressed = true;
                continue;
            }
            // FR-FCFS: (1) oldest row-hit whose column timing is ready,
            // (2) otherwise oldest request (activate/precharge as needed).
            // Rank-floor guard: if no column may issue this cycle under
            // rank-wide tCCD_S, skip the hit scan entirely (§Perf).
            // The hit scan runs on the per-bank bucket index: only banks
            // with an open row and ready column timing are walked, each to
            // its oldest live row-match — O(banks) per issue instead of
            // O(queue), identical pick order to the linear scan (the
            // global minimum virtual index over per-bank minima IS the
            // oldest ready hit; property-tested below).
            let col_possible = ch.rank.col_floor(cfg) <= cycle;
            let mut issue: Option<u64> = None; // oldest ready hit (virtual idx)
            if col_possible {
                for bidx in 0..ch.banks.len() {
                    let (open, rdwr) = {
                        let b = &ch.banks[bidx];
                        (b.open_row, b.next_rdwr)
                    };
                    let Some(row) = open else { continue };
                    if rdwr > cycle {
                        continue;
                    }
                    let rank = &ch.rank;
                    if let Some(v) = ch.queue.oldest_hit(bidx, row, cycle, |p| {
                        rank.col_ready(cfg, p.addr.bankgroup, p.is_write) <= cycle
                    }) {
                        if issue.map_or(true, |best| v < best) {
                            issue = Some(v);
                        }
                    }
                }
            }
            if issue.is_none() {
                // oldest request, make progress on its bank
                if let Some((qi, p)) = ch.queue.iter().find(|(_, p)| p.arrival <= cycle) {
                    let p = *p;
                    let bank = &mut ch.banks[p.bank];
                    match bank.open_row {
                        Some(r) if r == p.addr.row => { /* waiting on timing */ }
                        Some(_) => {
                            // conflict: precharge when allowed
                            if bank.next_pre <= cycle {
                                bank.open_row = None;
                                bank.next_act = bank.next_act.max(cycle + cfg.t_rp);
                                bank.row_conflicts += 1;
                                self.stats.row_conflicts += 1;
                                self.channel_stats[ci].row_conflicts += 1;
                                progressed = true;
                            }
                        }
                        None => {
                            // activate when allowed
                            if bank.next_act <= cycle
                                && ch.rank.act_ready(cfg, p.addr.bankgroup) <= cycle
                            {
                                bank.open_row = Some(p.addr.row);
                                bank.next_rdwr = cycle + cfg.t_rcd;
                                bank.next_pre = cycle + cfg.t_ras;
                                bank.next_act = cycle + cfg.t_rc;
                                ch.rank.record_act(p.addr.bankgroup, cycle);
                                bank.row_misses += 1;
                                self.stats.activates += 1;
                                self.stats.row_misses += 1;
                                self.channel_stats[ci].activates += 1;
                                self.channel_stats[ci].row_misses += 1;
                                progressed = true;
                            }
                        }
                    }
                    let _ = qi;
                }
            }
            if let Some(v) = issue {
                let p = ch.queue.remove(v);
                let bank = &mut ch.banks[p.bank];
                bank.row_hits += 1;
                self.stats.row_hits += 1;
                self.channel_stats[ci].row_hits += 1;
                ch.rank.record_col(cfg, p.addr.bankgroup, cycle, p.is_write);
                // data lands after CL/CWL + BL/2
                let lat = if p.is_write { cfg.cwl } else { cfg.cl };
                let finish = cycle + lat + cfg.burst_len as u64 / 2;
                if p.is_write {
                    self.stats.write_bursts += 1;
                    self.channel_stats[ci].write_bursts += 1;
                    // tWR after write data before precharge
                    bank.next_pre = bank.next_pre.max(finish + cfg.t_wr);
                } else {
                    self.stats.read_bursts += 1;
                    self.channel_stats[ci].read_bursts += 1;
                    bank.next_pre = bank.next_pre.max(cycle + cfg.t_rtp);
                }
                self.stats.total_latency += finish - p.arrival;
                self.channel_stats[ci].total_latency += finish - p.arrival;
                self.completions.push(Completion { tag: p.tag, finish });
                progressed = true;
            } else {
                // fruitless scan: suppress this channel until the next
                // O(1) lower bound on any issue — the rank-level floor
                // (no column/ACT can beat it), the oldest request's bank
                // timers, and the refresh boundary. Conservative (may
                // wake early), never late.
                let floor = ch.rank.issue_floor(cfg);
                if floor <= cycle {
                    // rank constraints already clear: some bank-level timer
                    // we don't track per-entry could unblock any cycle —
                    // rescan next cycle.
                    ch.skip_until = cycle + 1;
                } else {
                    let mut nxt = ch.next_refresh.min(floor);
                    let mut upd = |t: u64| {
                        if t > cycle && t < nxt {
                            nxt = t;
                        }
                    };
                    if let Some(p) = ch.queue.first() {
                        let b = &ch.banks[p.bank];
                        upd(p.arrival);
                        upd(b.next_act);
                        upd(b.next_pre);
                        upd(b.next_rdwr);
                    }
                    ch.skip_until = nxt.max(cycle + 1);
                }
            }
        }
        progressed
    }

    /// Convenience: simulate a read of `bytes` streaming bytes from `base`,
    /// return (total cycles, stats snapshot).
    pub fn run_stream_read(&mut self, base: u64, bytes: u64) -> u64 {
        self.enqueue_range(base, bytes, false, 0);
        self.drain()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::configs::ddr5::DDR5_4800_PAPER;

    fn sys() -> MemorySystem {
        MemorySystem::new(DDR5_4800_PAPER.clone())
    }

    #[test]
    fn single_read_latency_is_rcd_plus_cl() {
        let mut s = sys();
        s.enqueue(Request {
            addr: 0,
            is_write: false,
            arrival: 0,
            tag: 1,
        });
        s.drain();
        let c = s.take_completions();
        assert_eq!(c.len(), 1);
        let cfg = &DDR5_4800_PAPER;
        // ACT at some cycle t0>=0, RD at t0+tRCD, data at +CL+BL/2
        let min = cfg.t_rcd + cfg.cl + cfg.burst_len as u64 / 2;
        assert!(
            c[0].finish >= min && c[0].finish <= min + 4,
            "finish={} min={min}",
            c[0].finish
        );
    }

    #[test]
    fn streaming_read_approaches_peak_bandwidth() {
        let mut s = sys();
        let bytes = 4 << 20; // 4 MiB
        let cycles = s.run_stream_read(0, bytes);
        let cfg = &DDR5_4800_PAPER;
        let secs = cycles as f64 * cfg.t_ck();
        let bw = bytes as f64 / secs;
        let peak = cfg.peak_bw_per_channel() * cfg.channels as f64;
        let eff = bw / peak;
        assert!(
            eff > 0.75,
            "streaming efficiency {eff:.3} ({:.1} of {:.1} GB/s)",
            bw / 1e9,
            peak / 1e9
        );
    }

    #[test]
    fn row_hits_dominate_streaming() {
        let mut s = sys();
        s.run_stream_read(0, 1 << 20);
        assert!(
            s.stats.row_hits > s.stats.row_misses * 20,
            "hits={} misses={}",
            s.stats.row_hits,
            s.stats.row_misses
        );
    }

    #[test]
    fn random_reads_are_much_slower_than_streaming() {
        let cfg = &DDR5_4800_PAPER;
        let mut s = sys();
        let n = 4096u64;
        let mut tag = 0;
        let mut rng = crate::util::rng::Xoshiro256::new(1);
        for _ in 0..n {
            let addr = (rng.next_u64() % (1 << 30)) / 64 * 64;
            while !s.enqueue(Request {
                addr,
                is_write: false,
                arrival: s.now(),
                tag,
            }) {
                s.tick();
            }
            tag += 1;
        }
        let rand_cycles = s.drain();

        let mut s2 = sys();
        let stream_cycles = s2.run_stream_read(0, n * 64);
        assert!(
            rand_cycles > stream_cycles * 2,
            "random {rand_cycles} vs stream {stream_cycles}"
        );
        let _ = cfg;
    }

    #[test]
    fn energy_scales_with_traffic() {
        let mut a = sys();
        a.run_stream_read(0, 1 << 20);
        let ea = a.stats.energy_pj(&a.cfg).total_pj();
        let mut b = sys();
        b.run_stream_read(0, 2 << 20);
        let eb = b.stats.energy_pj(&b.cfg).total_pj();
        assert!(
            (eb / ea - 2.0).abs() < 0.25,
            "2x traffic should be ~2x energy: {ea:.0} -> {eb:.0}"
        );
    }

    #[test]
    fn writes_complete_and_count() {
        let mut s = sys();
        s.enqueue_range(0, 64 * 128, true, 0);
        s.drain();
        assert_eq!(s.stats.write_bursts, 128);
        assert_eq!(s.take_completions().len(), 128);
    }

    #[test]
    fn refresh_fires_on_long_runs() {
        let mut s = sys();
        // run long enough to cross tREFI several times
        s.run_stream_read(0, 8 << 20);
        if s.now() > s.cfg.t_refi * 2 {
            assert!(s.stats.refreshes >= 1);
        }
    }

    /// Sum of the non-`cycles` fields of every per-channel entry
    /// (`cycles` is the system-wide clock and lives only in the
    /// aggregate).
    fn channel_sum(s: &MemorySystem) -> SimStats {
        let mut sum = SimStats::default();
        for c in &s.channel_stats {
            assert_eq!(c.cycles, 0, "per-channel cycles must stay 0");
            sum.requests += c.requests;
            sum.read_bursts += c.read_bursts;
            sum.write_bursts += c.write_bursts;
            sum.activates += c.activates;
            sum.refreshes += c.refreshes;
            sum.row_hits += c.row_hits;
            sum.row_misses += c.row_misses;
            sum.row_conflicts += c.row_conflicts;
            sum.total_latency += c.total_latency;
            sum.retried_requests += c.retried_requests;
        }
        sum.cycles = s.stats.cycles;
        sum
    }

    #[test]
    fn per_channel_stats_sum_bit_exactly_to_aggregate() {
        let mut s = sys();
        let mut tag = 0u64;
        tag = s.enqueue_range(0, 64 * 512, false, tag);
        let mut rng = crate::util::rng::Xoshiro256::new(17);
        for _ in 0..256 {
            let addr = (rng.next_u64() % (1 << 28)) / 64 * 64;
            while !s.enqueue(Request {
                addr,
                is_write: rng.next_f64() < 0.25,
                arrival: s.now(),
                tag,
            }) {
                s.tick();
            }
            tag += 1;
        }
        s.enqueue_retry(128, 64 * 8);
        s.drain();
        assert_eq!(s.channel_stats.len(), s.cfg.channels);
        assert_eq!(channel_sum(&s), s.stats);
        // the interleaving actually spread traffic: >= 2 channels busy
        let busy = s.channel_stats.iter().filter(|c| c.requests > 0).count();
        assert!(busy >= 2, "expected multi-channel traffic, got {busy}");
    }

    #[test]
    fn channel_queues_are_independent() {
        // A probe request on one channel must complete at exactly the
        // same cycle whether or not another channel is saturated: the
        // per-channel FR-FCFS queues share only the clock.
        let cfg = DDR5_4800_PAPER.clone();
        assert!(cfg.channels >= 2);
        let map = AddrMap::new(&cfg);
        // find one 64 B-aligned address per channel
        let addr_on = |ch: usize| {
            (0..1u64 << 20)
                .map(|i| i * 64)
                .find(|&a| map.decode(a).channel == ch)
                .expect("address on channel")
        };
        let probe = Request {
            addr: addr_on(1),
            is_write: false,
            arrival: 0,
            tag: 999_999,
        };
        let run = |load_ch0: bool| {
            let mut s = sys();
            if load_ch0 {
                // saturate channel 0 with a long streaming run touching
                // only channel-0 addresses
                let mut tag = 0;
                let mut enq = 0;
                let mut a = 0u64;
                while enq < 48 {
                    if map.decode(a).channel == 0 {
                        while !s.enqueue(Request {
                            addr: a,
                            is_write: false,
                            arrival: 0,
                            tag,
                        }) {
                            s.tick();
                        }
                        tag += 1;
                        enq += 1;
                    }
                    a += 64;
                }
            }
            assert!(s.enqueue(probe));
            s.drain();
            s.take_completions()
                .into_iter()
                .find(|c| c.tag == probe.tag)
                .expect("probe completes")
                .finish
        };
        assert_eq!(run(false), run(true), "channel-0 load delayed channel 1");
    }

    #[test]
    fn fast_forward_is_cycle_exact_vs_naive_ticking() {
        fast_forward_equivalence(DDR5_4800_PAPER.clone());
    }

    #[test]
    fn fast_forward_is_cycle_exact_at_one_channel() {
        // the sharded serve path runs one MemorySystem per shard with
        // channels = 1 — the equivalence must hold there too
        let mut cfg = DDR5_4800_PAPER.clone();
        cfg.channels = 1;
        fast_forward_equivalence(cfg);
    }

    fn fast_forward_equivalence(cfg: Ddr5Config) {
        // Event skipping must change nothing observable: run the same
        // mixed workload (stream + scattered reads + writes) in both
        // modes and require identical cycle counts, stats, and
        // completion times.
        let run = |fast: bool| -> (u64, SimStats, Vec<Completion>) {
            let mut s = MemorySystem::new(cfg.clone());
            s.fast_forward = fast;
            let mut tag = 0u64;
            // streaming burst
            tag = s.enqueue_range(0, 64 * 256, false, tag);
            // scattered reads across banks/rows
            let mut rng = crate::util::rng::Xoshiro256::new(7);
            for _ in 0..192 {
                let addr = (rng.next_u64() % (1 << 28)) / 64 * 64;
                while !s.enqueue(Request {
                    addr,
                    is_write: false,
                    arrival: s.now(),
                    tag,
                }) {
                    s.tick();
                }
                tag += 1;
            }
            // a write burst to exercise turnaround timing
            s.enqueue_range(1 << 20, 64 * 64, true, tag);
            let cycles = s.drain();
            let mut comps = s.take_completions();
            comps.sort_by_key(|c| (c.tag, c.finish));
            assert_eq!(channel_sum(&s), s.stats, "channel split diverged");
            (cycles, s.stats.clone(), comps)
        };
        let (fc, fs, fcomp) = run(true);
        let (nc, ns, ncomp) = run(false);
        assert_eq!(fc, nc, "cycle count diverged: fast={fc} naive={nc}");
        assert_eq!(fs, ns, "stats diverged");
        assert_eq!(fcomp.len(), ncomp.len());
        for (a, b) in fcomp.iter().zip(&ncomp) {
            assert_eq!((a.tag, a.finish), (b.tag, b.finish));
        }
    }

    fn pending_at(
        map: &crate::dram::addrmap::AddrMap,
        cfg: &Ddr5Config,
        byte_addr: u64,
        step: u64,
    ) -> Pending {
        let addr = map.decode(byte_addr);
        Pending {
            addr,
            bank: addr.bankgroup * cfg.banks_per_group + addr.bank,
            is_write: false,
            arrival: step,
            tag: step,
        }
    }

    #[test]
    fn req_queue_matches_vec_reference() {
        // Random push/remove interleavings: the tombstoned queue must
        // preserve exactly the Vec's arrival order and removal results —
        // including the per-bank bucket index, which must mirror the Vec
        // filtered by bank at every step.
        let cfg = DDR5_4800_PAPER.clone();
        let map = crate::dram::addrmap::AddrMap::new(&cfg);
        let mut rng = crate::util::rng::Xoshiro256::new(9);
        let mut rq = ReqQueue::new(cfg.banks());
        let mut vr: Vec<Pending> = Vec::new();
        for step in 0..2000u64 {
            if rq.len() < 64 && (vr.is_empty() || rng.next_f64() < 0.55) {
                let p = pending_at(&map, &cfg, (rng.next_u64() % (1 << 28)) / 64 * 64, step);
                rq.push(p);
                vr.push(p);
            } else {
                let k = rng.index(vr.len());
                let (v, _) = rq.iter().nth(k).unwrap();
                let a = rq.remove(v);
                let b = vr.remove(k);
                assert_eq!((a.tag, a.arrival), (b.tag, b.arrival));
            }
            assert_eq!(rq.len(), vr.len());
            assert_eq!(rq.is_empty(), vr.is_empty());
            let tags: Vec<u64> = rq.iter().map(|(_, p)| p.tag).collect();
            let want: Vec<u64> = vr.iter().map(|p| p.tag).collect();
            assert_eq!(tags, want, "order diverged at step {step}");
            assert_eq!(rq.first().map(|p| p.tag), vr.first().map(|p| p.tag));
            // bucket index == Vec filtered by bank, in age order
            for b in 0..cfg.banks() {
                let got: Vec<u64> = rq.bank_live(b).iter().map(|p| p.tag).collect();
                let want: Vec<u64> =
                    vr.iter().filter(|p| p.bank == b).map(|p| p.tag).collect();
                assert_eq!(got, want, "bank {b} bucket diverged at step {step}");
            }
        }
    }

    #[test]
    fn bucket_hit_scan_matches_linear_reference() {
        // The bucketed oldest_hit must return exactly what the old linear
        // age-order scan returned, for random queues, open rows, and
        // readiness predicates — the equivalence tick_issue's O(banks)
        // scan rests on.
        let cfg = DDR5_4800_PAPER.clone();
        let map = crate::dram::addrmap::AddrMap::new(&cfg);
        let mut rng = crate::util::rng::Xoshiro256::new(31);
        let mut rq = ReqQueue::new(cfg.banks());
        let mut vr: Vec<Pending> = Vec::new();
        for step in 0..3000u64 {
            // churn: push with random (sometimes future) arrivals, remove
            // randomly to create tombstones and force compactions
            if rq.len() < 48 && (vr.is_empty() || rng.next_f64() < 0.6) {
                let mut p =
                    pending_at(&map, &cfg, (rng.next_u64() % (1 << 26)) / 64 * 64, step);
                if rng.next_f64() < 0.2 {
                    p.arrival = step + 1 + rng.next_u64() % 5; // not yet arrived
                }
                rq.push(p);
                vr.push(p);
            } else {
                let k = rng.index(vr.len());
                let (v, _) = rq.iter().nth(k).unwrap();
                rq.remove(v);
                vr.remove(k);
            }
            // a random readiness predicate, deterministic per entry
            let salt = rng.next_u64();
            let ready =
                |p: &Pending| (p.tag ^ p.addr.column as u64 ^ salt).wrapping_mul(0x9E37) % 4 != 0;
            // compare per (bank, row) for a sample of rows present
            for _ in 0..4 {
                if vr.is_empty() {
                    break;
                }
                let probe = vr[rng.index(vr.len())];
                let (bank, row) = (probe.bank, probe.addr.row);
                let linear = vr
                    .iter()
                    .find(|p| {
                        p.bank == bank && p.addr.row == row && p.arrival <= step && ready(p)
                    })
                    .map(|p| p.tag);
                let bucketed = rq
                    .oldest_hit(bank, row, step, ready)
                    .map(|v| rq.get(v).unwrap().tag);
                assert_eq!(bucketed, linear, "step {step} bank {bank} row {row}");
            }
        }
    }

    #[test]
    fn backpressure_reports_full_queue() {
        let mut s = sys();
        s.queue_depth = 2;
        let mut accepted = 0;
        for i in 0..10 {
            if s.enqueue(Request {
                addr: i * 64 * 4, // same channel? stride 256 B = ch 0 every 4th
                is_write: false,
                arrival: 0,
                tag: i,
            }) {
                accepted += 1;
            }
        }
        assert!(accepted < 10);
    }
}
