//! DDR5 memory-system simulator (DRAMSim3 substitute): cycle-level bank /
//! bank-group / rank timing, FR-FCFS scheduling, address interleaving, and
//! an IDD-based energy model. Configured as the paper's testbed: 4 channels
//! of DDR5-4800 with 10 ×4 devices each (`configs::ddr5::DDR5_4800_PAPER`).
pub mod addrmap;
pub mod bank;
pub mod sharded;
pub mod sim;

pub use addrmap::{AddrMap, Address};
pub use sharded::{home_shard, ShardedMemSystem};
pub use sim::{
    modeled_read_energy_fj, Completion, EnergyBreakdown, MemorySystem, Request, SimStats,
};
