//! Physical address → (channel, bankgroup, bank, row, column) mapping.
//!
//! Default scheme is DRAMSim3's `rochbabgco`-style interleaving tuned for
//! streaming reads: channel bits lowest (above the 64 B burst offset) so
//! consecutive cache lines stripe across channels, then **bank group and
//! bank** so back-to-back column commands alternate bank groups and run at
//! tCCD_S (seamless bursts), then column, then row.

use crate::configs::ddr5::Ddr5Config;

/// Decoded DRAM coordinates.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct Address {
    pub channel: usize,
    pub bankgroup: usize,
    pub bank: usize,
    pub row: usize,
    pub column: usize,
}

/// Address mapper for a given device configuration.
#[derive(Debug, Clone)]
pub struct AddrMap {
    burst_shift: u32,
    ch_bits: u32,
    co_bits: u32,
    bg_bits: u32,
    ba_bits: u32,
    channels: usize,
    columns: usize,
    bankgroups: usize,
    banks: usize,
}

impl AddrMap {
    pub fn new(cfg: &Ddr5Config) -> Self {
        let burst = cfg.burst_bytes();
        assert!(burst.is_power_of_two());
        Self {
            burst_shift: burst.trailing_zeros(),
            ch_bits: log2c(cfg.channels),
            co_bits: log2c(cfg.columns),
            bg_bits: log2c(cfg.bankgroups),
            ba_bits: log2c(cfg.banks_per_group),
            channels: cfg.channels,
            columns: cfg.columns,
            bankgroups: cfg.bankgroups,
            banks: cfg.banks_per_group,
        }
    }

    /// Map a byte address to DRAM coordinates (bursts are 64 B aligned).
    pub fn decode(&self, byte_addr: u64) -> Address {
        let mut a = byte_addr >> self.burst_shift;
        let channel = (a & mask(self.ch_bits)) as usize % self.channels.max(1);
        a >>= self.ch_bits;
        let bankgroup = (a & mask(self.bg_bits)) as usize % self.bankgroups.max(1);
        a >>= self.bg_bits;
        let bank = (a & mask(self.ba_bits)) as usize % self.banks.max(1);
        a >>= self.ba_bits;
        let column = (a & mask(self.co_bits)) as usize % self.columns.max(1);
        a >>= self.co_bits;
        Address {
            channel,
            bankgroup,
            bank,
            row: a as usize,
            column,
        }
    }
}

#[inline]
fn mask(bits: u32) -> u64 {
    (1u64 << bits) - 1
}

#[inline]
fn log2c(n: usize) -> u32 {
    (usize::BITS - (n.max(1) - 1).leading_zeros()).min(usize::BITS - 1)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::configs::ddr5::DDR5_4800_PAPER;

    #[test]
    fn consecutive_lines_stripe_channels() {
        let m = AddrMap::new(&DDR5_4800_PAPER);
        let a0 = m.decode(0);
        let a1 = m.decode(64);
        let a2 = m.decode(128);
        assert_eq!(a0.channel, 0);
        assert_eq!(a1.channel, 1);
        assert_eq!(a2.channel, 2);
        assert_eq!(a0.row, a1.row);
    }

    #[test]
    fn consecutive_lines_alternate_bank_groups_within_channel() {
        let m = AddrMap::new(&DDR5_4800_PAPER);
        // per-channel consecutive lines (stride = channels * 64 B) must
        // walk the bank groups so column commands run at tCCD_S
        let a = m.decode(0);
        let b = m.decode(4 * 64);
        let c = m.decode(8 * 64);
        assert_eq!(a.channel, b.channel);
        assert_ne!(a.bankgroup, b.bankgroup);
        assert_ne!(b.bankgroup, c.bankgroup);
    }

    #[test]
    fn sequential_stream_revisits_same_row_across_bank_sweep() {
        let m = AddrMap::new(&DDR5_4800_PAPER);
        // one full bank sweep per channel = bg*banks lines; the next visit
        // to the same bank is the next column of the same row
        let sweep = 4u64 * 8 * 4 * 64; // channels * bgs * banks * line
        let a = m.decode(0);
        let b = m.decode(sweep);
        assert_eq!(a.channel, b.channel);
        assert_eq!(a.bankgroup, b.bankgroup);
        assert_eq!(a.bank, b.bank);
        assert_eq!(a.row, b.row);
        assert_eq!(b.column, a.column + 1);
    }

    #[test]
    fn decode_covers_all_banks() {
        let m = AddrMap::new(&DDR5_4800_PAPER);
        let mut seen = std::collections::HashSet::new();
        for i in 0..4096u64 {
            let a = m.decode(i * 64);
            seen.insert((a.channel, a.bankgroup, a.bank));
        }
        // 4 channels * 8 bg * 4 banks = 128 combos; a 256 KiB stream
        // should touch many of them
        assert!(seen.len() >= 32, "only {} bank combos", seen.len());
    }

    #[test]
    fn distinct_addresses_distinct_coords() {
        let m = AddrMap::new(&DDR5_4800_PAPER);
        let a = m.decode(0);
        let b = m.decode(1 << 30);
        assert_ne!(a, b);
    }
}
