//! The P-vs-T weight-traffic model behind Figs 10 and 11.
//!
//! For a model with storage precision `base` running under a dynamic-
//! quantization precision distribution (Fig 9):
//!
//! * **T (traditional byte-level)** stores values byte-aligned and can
//!   fetch precision only at byte granularity: a weight read at level L
//!   moves `ceil(bits(L)/8)` bytes.
//! * **P (proposed bit-plane)** stores per-plane *compressed* frames and
//!   fetches the top `bits(L)` planes: a weight read at level L moves the
//!   measured compressed size of those planes (+ amortized header).
//!
//! The per-plane compressed sizes are *measured* on data (synthetic
//! calibrated checkpoints, or real tensors), not assumed.

use crate::bitplane::layout::disaggregate;
use crate::compress::{codec::block_compressed_size, Codec};
use crate::fmt::Dtype;
use crate::memctrl::frame::FrameHeader;
use crate::quant::mode::PrecisionDist;

/// Measured per-plane compressed fractions for a tensor population.
#[derive(Debug, Clone)]
pub struct WeightTraffic {
    pub base: Dtype,
    /// For plane p (MSB first): compressed bytes / raw bytes of that plane.
    pub plane_frac: Vec<f64>,
    /// Amortized header bits per weight.
    pub header_bits: f64,
}

impl WeightTraffic {
    /// Measure plane compressibility of `codes` under `codec` with the
    /// paper's 4 KB blocks.
    pub fn measure(base: Dtype, codes: &[u16], codec: Codec) -> Self {
        let pb = disaggregate(base, codes);
        let plane_frac = pb
            .planes()
            .map(|p| {
                if p.is_empty() {
                    1.0
                } else {
                    block_compressed_size(codec, p, 4096) as f64 / p.len() as f64
                }
            })
            .collect();
        // header: one frame per 4 KB logical block
        let codes_per_block = 4096 * 8 / base.bits() as usize;
        let h = FrameHeader {
            kind: crate::memctrl::FrameKind::Weights,
            dtype: base,
            codec,
            m: codes_per_block,
            channels: 0,
            mode: 0,
            plane_len: vec![(0, false); base.bits() as usize],
            plane_sum: vec![0; base.bits() as usize],
        };
        let header_bits = h.header_bytes() as f64 * 8.0 / codes_per_block as f64;
        Self {
            base,
            plane_frac,
            header_bits,
        }
    }

    /// P: average *fetched* bits per weight when reading the top `keep`
    /// planes.
    pub fn p_bits(&self, keep: u32) -> f64 {
        let keep = (keep as usize).min(self.plane_frac.len());
        self.header_bits + self.plane_frac[..keep].iter().sum::<f64>()
    }

    /// T: byte-granular fetch for `level` bits. A byte-level layout can
    /// slice a multi-byte container at byte boundaries (read 1 of a BF16's
    /// 2 bytes for FP8), but a sub-byte container (INT4/INT2 packed
    /// 2–4 per byte) cannot be sliced further — the whole container moves.
    pub fn t_bits(&self, level: u32) -> f64 {
        let container = self.base.bits() as f64;
        if container <= 8.0 {
            container.min(((level as f64 / 8.0).ceil() * 8.0).max(container))
        } else {
            ((level as f64 / 8.0).ceil() * 8.0).min(container)
        }
    }

    /// Average bits per weight under a precision distribution, for both
    /// layouts: `(p_avg, t_avg)`.
    pub fn avg_bits(&self, dist: &PrecisionDist) -> (f64, f64) {
        let mut p = 0.0;
        let mut t = 0.0;
        for (d, &f) in dist.levels.iter().zip(&dist.fractions) {
            let eff = d.bits().min(self.base.bits());
            p += f * self.p_bits(eff);
            t += f * self.t_bits(eff);
        }
        (p, t)
    }
}

/// Convenience: average effective (ideal, unrounded) bits for a dist.
pub fn avg_bits_per_weight(dist: &PrecisionDist) -> f64 {
    dist.avg_bits()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::configs::LLAMA31_8B;
    use crate::quant::mode::RouterSim;
    use crate::synth::{encode_checkpoint, sample_checkpoint};

    fn traffic(base: Dtype) -> WeightTraffic {
        let ts = sample_checkpoint(&LLAMA31_8B, 1 << 17, 42);
        let t = encode_checkpoint(&ts, base);
        WeightTraffic::measure(base, &t.codes, Codec::Zstd)
    }

    #[test]
    fn full_precision_p_matches_region_ratio() {
        let tr = traffic(Dtype::Bf16);
        let p16 = tr.p_bits(16);
        // should land near 16 / 1.34 ≈ 11.9 bits (Table III band)
        assert!((10.5..13.5).contains(&p16), "p16={p16}");
        assert_eq!(tr.t_bits(16), 16.0);
    }

    #[test]
    fn p_scales_proportionally_t_staircases() {
        let tr = traffic(Dtype::Bf16);
        // P at 12 planes < P at 16 planes; T at 12 bits == 16 bits (2 bytes)
        assert!(tr.p_bits(12) < tr.p_bits(16));
        assert_eq!(tr.t_bits(12), 16.0);
        assert_eq!(tr.t_bits(8), 8.0);
        assert_eq!(tr.t_bits(4), 8.0); // bf16 container, byte floor
        assert!(tr.p_bits(8) < tr.p_bits(12));
        // exponent planes compress: top-8 fetch well under 8 bits
        assert!(tr.p_bits(8) < 7.0, "p8={}", tr.p_bits(8));
    }

    #[test]
    fn fig10_savings_band_bf16() {
        // With the paper's router distribution, P should save ~25–30%
        // over T for BF16-based models.
        let tr = traffic(Dtype::Bf16);
        let r = RouterSim::paper_default("LLaMA 3.1 8B");
        let d = r.simulate(Dtype::Bf16, 2000, 64, 7);
        let (p, t) = tr.avg_bits(&d);
        let savings = 1.0 - p / t;
        assert!(
            (0.22..0.38).contains(&savings),
            "bf16 P-vs-T savings {savings:.3} (p={p:.2} t={t:.2})"
        );
    }

    #[test]
    fn savings_shrink_with_base_precision() {
        // Fig 10's trend: savings decrease from BF16 to FP8 to INT4 bases.
        let s = |base: Dtype, name: &str| {
            let tr = traffic(base);
            let r = RouterSim::paper_default(name);
            let d = r.simulate(base, 2000, 64, 11);
            let (p, t) = tr.avg_bits(&d);
            1.0 - p / t
        };
        let bf16 = s(Dtype::Bf16, "LLaMA 3.1 8B");
        let fp8 = s(Dtype::Fp8E4M3, "LLaMA 3.1 8B");
        let int4 = s(Dtype::Int4, "LLaMA 3.1 8B");
        assert!(
            bf16 > fp8 && fp8 > int4,
            "bf16={bf16:.3} fp8={fp8:.3} int4={int4:.3}"
        );
        assert!(int4 >= -0.05, "int4 savings should not be very negative: {int4:.3}");
    }

    #[test]
    fn header_overhead_is_small() {
        let tr = traffic(Dtype::Bf16);
        assert!(tr.header_bits < 0.5, "header bits/weight = {}", tr.header_bits);
    }
}
