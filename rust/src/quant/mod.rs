//! Dynamic quantization policies (paper §II-C, Figs 2/3/9).
//!
//! * [`mode`] — MoDE-style routers that assign a precision level to each
//!   model component per token (Fig 2), producing the precision
//!   distributions of Fig 9.
//! * [`policy`] — KV-cache retention/precision policies compared in
//!   Table II (full cache, sliding window, Quest-style top-k pages,
//!   dynamic multi-tier quantization).
//! * [`traffic`] — the P-vs-T per-weight DRAM traffic model that feeds
//!   Figs 10 and 11.
pub mod mode;
pub mod policy;
pub mod traffic;

pub use mode::{precision_menu, PrecisionDist, RouterSim};
pub use policy::{KvPolicy, PageTier};
pub use traffic::{avg_bits_per_weight, WeightTraffic};
