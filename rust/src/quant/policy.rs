//! KV-cache retention / precision policies (Table II).
//!
//! A policy decides, per attention page (16 tokens, as in Quest), at which
//! precision the page's K/V entries are fetched — or whether they are
//! fetched at all. Page importance is the Quest criterion: an upper bound
//! on the page's attention mass computed from per-page min/max key
//! metadata against the current query.

use crate::fmt::Dtype;

/// Tokens per page (Quest's page size, also the paper's).
pub const PAGE_TOKENS: usize = 16;

/// One tier of a dynamic-quantization policy: the `pages` most important
/// pages (after more important tiers are assigned) read at `dtype`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PageTier {
    pub pages: usize,
    pub dtype: Dtype,
}

/// The policies compared in Table II.
#[derive(Debug, Clone, PartialEq)]
pub enum KvPolicy {
    /// Attend to the full cache at base precision.
    Full,
    /// Attend only to the last `window` tokens (plus attention sinks).
    SlidingWindow { window: usize },
    /// Quest: top-`pages` pages at base precision, others skipped.
    QuestTopK { pages: usize },
    /// The paper's dynamic quantization: tiered precisions by importance;
    /// pages beyond all tiers are skipped.
    DynamicQuant { tiers: Vec<PageTier> },
}

impl KvPolicy {
    /// Table II's configurations.
    pub fn table2() -> Vec<(String, KvPolicy)> {
        vec![
            ("Full KV Cache".into(), KvPolicy::Full),
            (
                "Sliding Window (64 tokens)".into(),
                KvPolicy::SlidingWindow { window: 64 },
            ),
            (
                "Quest (Top 5 pages in BF16)".into(),
                KvPolicy::QuestTopK { pages: 5 },
            ),
            (
                "Dyn. Quant (5 BF16 + 3 FP8 + 2 FP4)".into(),
                KvPolicy::DynamicQuant {
                    tiers: vec![
                        PageTier { pages: 5, dtype: Dtype::Bf16 },
                        PageTier { pages: 3, dtype: Dtype::Fp8E4M3 },
                        PageTier { pages: 2, dtype: Dtype::Fp4 },
                    ],
                },
            ),
            (
                "Dyn. Quant (5 BF16 + 5 FP8)".into(),
                KvPolicy::DynamicQuant {
                    tiers: vec![
                        PageTier { pages: 5, dtype: Dtype::Bf16 },
                        PageTier { pages: 5, dtype: Dtype::Fp8E4M3 },
                    ],
                },
            ),
        ]
    }

    /// Given descending-importance page ranks (0 = most important) and the
    /// current position, return per-page effective precision in bit-planes
    /// kept (0 = skip). `npages` includes the current partial page, which
    /// is always read at full precision (it holds the newest tokens).
    pub fn page_precisions(&self, npages: usize, base: Dtype, ranks: &[usize]) -> Vec<u32> {
        let mut out = Vec::with_capacity(npages);
        self.page_precisions_into(npages, base, ranks, &mut out);
        out
    }

    /// [`KvPolicy::page_precisions`] writing into a reusable buffer — the
    /// steady-state entry the per-step view planner
    /// ([`crate::coordinator::PolicyEngine::plan_pressured_into`]) uses so
    /// planning a decode step allocates nothing. Identical output.
    pub fn page_precisions_into(
        &self,
        npages: usize,
        base: Dtype,
        ranks: &[usize],
        out: &mut Vec<u32>,
    ) {
        assert_eq!(ranks.len(), npages);
        let full = base.bits();
        out.clear();
        match self {
            KvPolicy::Full => out.extend(std::iter::repeat(full).take(npages)),
            KvPolicy::SlidingWindow { window } => {
                let keep_pages = window.div_ceil(PAGE_TOKENS);
                out.extend((0..npages).map(|p| if p + keep_pages >= npages { full } else { 0 }));
            }
            KvPolicy::QuestTopK { pages } => {
                out.extend(ranks.iter().enumerate().map(|(p, &r)| {
                    if r < *pages || p + 1 == npages {
                        full
                    } else {
                        0
                    }
                }));
            }
            KvPolicy::DynamicQuant { tiers } => {
                // tier boundaries in rank space (tier lists are tiny and
                // fixed per policy; this is the one O(tiers) allocation)
                let mut bounds = Vec::with_capacity(tiers.len());
                let mut acc = 0usize;
                for t in tiers {
                    acc += t.pages;
                    bounds.push((acc, t.dtype));
                }
                out.extend(ranks.iter().enumerate().map(|(p, &r)| {
                    if p + 1 == npages {
                        return full;
                    }
                    for &(b, d) in &bounds {
                        if r < b {
                            return d.bits().min(full);
                        }
                    }
                    0
                }));
            }
        }
    }

    /// Average fetched bits per KV element for `npages` pages (assuming
    /// uniform page sizes) — the bandwidth proxy used in examples.
    pub fn avg_kv_bits(&self, npages: usize, base: Dtype, ranks: &[usize]) -> f64 {
        let ps = self.page_precisions(npages, base, ranks);
        ps.iter().map(|&b| b as f64).sum::<f64>() / npages.max(1) as f64
    }
}

/// Degrade-escalation under memory/bandwidth pressure: clamp every page's
/// fetch precision to at most `clamp` bit-planes — except the current
/// (newest) page, which always reads at full precision, and pages a
/// policy already skips (0 stays 0). This is how the scheduler tightens
/// *any* tenant policy mechanically — a `Full` tenant becomes an
/// everything-at-FP8 tenant at `clamp = 8` — spending read precision
/// (the paper's dynamic quantization) before it spends residency
/// (eviction).
pub fn apply_pressure(bits: &mut [u32], clamp: u32) {
    let n = bits.len();
    for (p, b) in bits.iter_mut().enumerate() {
        if p + 1 == n {
            continue; // current page: newest tokens stay full precision
        }
        if *b > clamp {
            *b = clamp;
        }
    }
}

/// Quest-style page importance from per-page key metadata: for query `q`,
/// score_p = Σ_j max(q_j · min_j(p), q_j · max_j(p)) — an upper bound on
/// any token's dot product within the page.
pub fn quest_scores(q: &[f32], page_min: &[Vec<f32>], page_max: &[Vec<f32>]) -> Vec<f64> {
    page_min
        .iter()
        .zip(page_max)
        .map(|(mn, mx)| {
            q.iter()
                .zip(mn.iter().zip(mx))
                .map(|(&qj, (&a, &b))| (qj * a).max(qj * b) as f64)
                .sum()
        })
        .collect()
}

/// Ranks (0 = highest score) from scores.
pub fn ranks_from_scores(scores: &[f64]) -> Vec<usize> {
    let mut ranks = Vec::new();
    let mut idx = Vec::new();
    ranks_from_scores_into(scores, &mut ranks, &mut idx);
    ranks
}

/// [`ranks_from_scores`] writing into reusable buffers (`idx` is sort
/// scratch), allocation-free in steady state. Ties break toward the lower
/// page index — exactly the stable-sort order [`ranks_from_scores`] has
/// always produced — via an explicit index tie-break on the unstable
/// (allocation-free) sort.
pub fn ranks_from_scores_into(scores: &[f64], ranks: &mut Vec<usize>, idx: &mut Vec<usize>) {
    idx.clear();
    idx.extend(0..scores.len());
    idx.sort_unstable_by(|&a, &b| {
        scores[b]
            .partial_cmp(&scores[a])
            .unwrap()
            .then_with(|| a.cmp(&b))
    });
    ranks.clear();
    ranks.resize(scores.len(), 0);
    for (r, &p) in idx.iter().enumerate() {
        ranks[p] = r;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn full_policy_keeps_everything() {
        let p = KvPolicy::Full;
        let ranks: Vec<usize> = (0..10).collect();
        assert_eq!(p.page_precisions(10, Dtype::Bf16, &ranks), vec![16; 10]);
    }

    #[test]
    fn sliding_window_keeps_tail() {
        let p = KvPolicy::SlidingWindow { window: 64 }; // 4 pages
        let ranks: Vec<usize> = (0..10).collect();
        let ps = p.page_precisions(10, Dtype::Bf16, &ranks);
        assert_eq!(&ps[..6], &[0; 6]);
        assert_eq!(&ps[6..], &[16; 4]);
    }

    #[test]
    fn quest_keeps_top_k_and_current() {
        let p = KvPolicy::QuestTopK { pages: 2 };
        // page 3 is most important, then page 0
        let scores = vec![5.0, 1.0, 0.5, 9.0, 2.0];
        let ranks = ranks_from_scores(&scores);
        let ps = p.page_precisions(5, Dtype::Bf16, &ranks);
        assert_eq!(ps, vec![16, 0, 0, 16, 16]); // 0 and 3 top-2; 4 = current
    }

    #[test]
    fn dynamic_quant_tiers_descend() {
        let p = KvPolicy::table2()[3].1.clone();
        let scores: Vec<f64> = (0..12).map(|i| -(i as f64)).collect(); // page 0 best
        let ranks = ranks_from_scores(&scores);
        let ps = p.page_precisions(12, Dtype::Bf16, &ranks);
        assert_eq!(&ps[..5], &[16; 5]);
        assert_eq!(&ps[5..8], &[8; 3]);
        assert_eq!(&ps[8..10], &[4; 2]);
        assert_eq!(ps[10], 0);
        assert_eq!(ps[11], 16); // current page
    }

    #[test]
    fn avg_bits_ordering_matches_traffic_intuition() {
        let scores: Vec<f64> = (0..32).map(|i| 32.0 - i as f64).collect();
        let ranks = ranks_from_scores(&scores);
        let table2 = KvPolicy::table2();
        let avg = |p: &KvPolicy| p.avg_kv_bits(32, Dtype::Bf16, &ranks);
        let full = avg(&table2[0].1);
        let sw = avg(&table2[1].1);
        let quest = avg(&table2[2].1);
        let dq = avg(&table2[4].1);
        assert!(full > dq && dq > quest && quest >= sw * 0.9, "{full} {dq} {quest} {sw}");
    }

    #[test]
    fn pressure_clamps_all_but_current_and_skipped() {
        let mut bits = vec![16, 8, 0, 16, 16];
        apply_pressure(&mut bits, 8);
        assert_eq!(bits, vec![8, 8, 0, 8, 16]);
        apply_pressure(&mut bits, 4);
        assert_eq!(bits, vec![4, 4, 0, 4, 16]);
        // clamp above current precision is a no-op
        let mut b2 = vec![4, 16];
        apply_pressure(&mut b2, 8);
        assert_eq!(b2, vec![4, 16]);
    }

    #[test]
    fn quest_scores_prefer_aligned_pages() {
        let q = vec![1.0f32, -1.0];
        let pmin = vec![vec![0.9f32, -1.1], vec![-0.1, -0.1]];
        let pmax = vec![vec![1.1f32, -0.9], vec![0.1, 0.1]];
        let s = quest_scores(&q, &pmin, &pmax);
        assert!(s[0] > s[1]);
    }

    #[test]
    fn ranks_into_matches_allocating_path_with_ties() {
        // the reusable-buffer variant must reproduce the historical stable
        // ordering, including tie-breaks toward the lower page index
        let mut r = crate::util::rng::Xoshiro256::new(77);
        let mut ranks = Vec::new();
        let mut idx = Vec::new();
        for _ in 0..200 {
            let n = (r.next_u64() % 24) as usize;
            // coarse values force frequent ties
            let scores: Vec<f64> = (0..n).map(|_| (r.next_u64() % 5) as f64).collect();
            let want = {
                let mut idx: Vec<usize> = (0..scores.len()).collect();
                idx.sort_by(|&a, &b| scores[b].partial_cmp(&scores[a]).unwrap());
                let mut ranks = vec![0usize; scores.len()];
                for (rk, &p) in idx.iter().enumerate() {
                    ranks[p] = rk;
                }
                ranks
            };
            ranks_from_scores_into(&scores, &mut ranks, &mut idx);
            assert_eq!(ranks, want, "scores={scores:?}");
            assert_eq!(ranks_from_scores(&scores), want);
        }
    }

    #[test]
    fn page_precisions_into_reuses_buffer() {
        let p = KvPolicy::table2()[3].1.clone();
        let scores: Vec<f64> = (0..12).map(|i| -(i as f64)).collect();
        let ranks = ranks_from_scores(&scores);
        let want = p.page_precisions(12, Dtype::Bf16, &ranks);
        let mut buf = vec![99u32; 40]; // stale contents must be cleared
        p.page_precisions_into(12, Dtype::Bf16, &ranks, &mut buf);
        assert_eq!(buf, want);
    }

    #[test]
    fn ranks_are_a_permutation() {
        let scores = vec![0.3, 0.1, 0.9, 0.5];
        let r = ranks_from_scores(&scores);
        let mut sorted = r.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, vec![0, 1, 2, 3]);
        assert_eq!(r[2], 0); // highest score
    }
}
