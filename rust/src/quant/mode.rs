//! MoDE (Mixture-of-Depths-and-Experts) router simulation.
//!
//! The paper adapts each model into a MoDE architecture where per-block
//! routers choose, per token, the precision at which each component's
//! weights are fetched (Fig 2). We model router behaviour statistically:
//! component importance follows the heavy-tailed softmax-mass distribution
//! observed for expert routing (a few components matter a lot per token,
//! most matter little), and the router maps importance quantiles to the
//! precision menu. Router layers themselves always run in BF16 (as in the
//! paper's setup).

use crate::fmt::Dtype;
use crate::util::rng::Xoshiro256;

/// The precision menu for a given base (storage) precision — Fig 9's
/// per-base sweeps.
pub fn precision_menu(base: Dtype) -> &'static [Dtype] {
    match base {
        Dtype::Bf16 => &[
            Dtype::Bf16,
            Dtype::Fp12,
            Dtype::Fp8E4M3,
            Dtype::Fp6,
            Dtype::Fp4,
        ],
        Dtype::Fp8E4M3 | Dtype::Fp8E5M2 => &[Dtype::Fp8E4M3, Dtype::Fp6, Dtype::Fp4],
        Dtype::Int4 => &[Dtype::Int4, Dtype::Int2],
        other => {
            // degenerate menus for completeness
            match other {
                Dtype::Fp16 => &[Dtype::Fp16, Dtype::Fp12, Dtype::Fp8E4M3, Dtype::Fp4],
                _ => &[Dtype::Fp4],
            }
        }
    }
}

/// A measured precision distribution: fraction of weight-bytes fetched at
/// each menu level (sums to 1).
#[derive(Debug, Clone)]
pub struct PrecisionDist {
    pub base: Dtype,
    pub levels: Vec<Dtype>,
    pub fractions: Vec<f64>,
}

impl PrecisionDist {
    /// Average effective bits per weight under this distribution.
    pub fn avg_bits(&self) -> f64 {
        self.levels
            .iter()
            .zip(&self.fractions)
            .map(|(d, f)| d.bits() as f64 * f)
            .sum()
    }

    /// Average *byte-rounded* bits (what a byte-level layout must fetch).
    pub fn avg_byte_bits(&self) -> f64 {
        self.levels
            .iter()
            .zip(&self.fractions)
            .map(|(d, f)| (d.bits() as f64 / 8.0).ceil() * 8.0 * f)
            .sum()
    }
}

/// Router simulator: draws per-token, per-component importance and maps
/// quantiles to the menu.
pub struct RouterSim {
    /// Importance concentration (higher = heavier tail = more weight on
    /// the top precision). Mixtral-style top-2-of-8 routing is spikier
    /// than LLaMA-MoE top-4-of-16.
    pub concentration: f64,
    /// Quantile edges (len = menu len - 1), descending importance.
    pub edges: Vec<f64>,
    /// Fraction of components that are router/norm layers pinned to base
    /// precision.
    pub pinned_frac: f64,
}

impl RouterSim {
    /// Defaults calibrated so the induced P-vs-T savings land in the
    /// paper's Fig 10/11 bands (~26–30% for BF16 bases, shrinking with
    /// base precision): routing is top-heavy — most weight traffic stays
    /// at base precision, with a meaningful mid tier and a small FP4 tail
    /// (plus the always-BF16 router layers).
    pub fn paper_default(model_name: &str) -> Self {
        // MoE models route harder (spikier importance) than dense-adapted
        let concentration = if model_name.contains("Mixtral") {
            1.35
        } else if model_name.contains("MoE") {
            1.15
        } else {
            1.0
        };
        Self {
            concentration,
            edges: vec![0.65, 0.77, 0.89, 0.96],
            pinned_frac: 0.02,
        }
    }

    /// Simulate `tokens × components` routing decisions; returns the
    /// fraction of weight traffic at each menu level.
    pub fn simulate(
        &self,
        base: Dtype,
        tokens: usize,
        components: usize,
        seed: u64,
    ) -> PrecisionDist {
        let menu = precision_menu(base);
        let mut counts = vec![0u64; menu.len()];
        let mut pinned = 0u64;
        let mut rng = Xoshiro256::new(seed ^ 0x4D6F4445);
        // edges for a menu shorter than 5: rescale the default edges
        let edges: Vec<f64> = if menu.len() >= 2 {
            (1..menu.len())
                .map(|i| {
                    let t = i as f64 / menu.len() as f64;
                    // interpolate the default edge curve
                    interp_edge(&self.edges, t)
                })
                .collect()
        } else {
            Vec::new()
        };
        for _ in 0..tokens {
            for _ in 0..components {
                if rng.next_f64() < self.pinned_frac {
                    pinned += 1;
                    continue;
                }
                // importance rank quantile: heavy-tailed via powering
                let q = rng.next_f64().powf(self.concentration);
                // q near 0 = most important
                let mut level = edges.len();
                for (i, &e) in edges.iter().enumerate() {
                    if q < e {
                        level = i;
                        break;
                    }
                }
                counts[level] += 1;
            }
        }
        counts[0] += pinned; // pinned components read at base precision
        let total: u64 = counts.iter().sum();
        PrecisionDist {
            base,
            levels: menu.to_vec(),
            fractions: counts.iter().map(|&c| c as f64 / total as f64).collect(),
        }
    }
}

fn interp_edge(edges: &[f64], t: f64) -> f64 {
    // piecewise-linear through (i/(n), edges[i-1]) with (0,0) and (1,1)
    let n = edges.len();
    let xs: Vec<f64> = (0..=n + 1)
        .map(|i| i as f64 / (n + 1) as f64)
        .collect();
    let mut ys = vec![0.0];
    ys.extend_from_slice(edges);
    ys.push(1.0);
    for w in 0..=n {
        if t <= xs[w + 1] {
            let f = (t - xs[w]) / (xs[w + 1] - xs[w]);
            return ys[w] + f * (ys[w + 1] - ys[w]);
        }
    }
    1.0
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn menus_are_descending_bits() {
        for base in [Dtype::Bf16, Dtype::Fp8E4M3, Dtype::Int4] {
            let m = precision_menu(base);
            assert_eq!(m[0], base);
            for w in m.windows(2) {
                assert!(w[0].bits() > w[1].bits());
            }
        }
    }

    #[test]
    fn distribution_sums_to_one_and_covers_menu() {
        let r = RouterSim::paper_default("LLaMA 3.1 8B");
        let d = r.simulate(Dtype::Bf16, 500, 64, 1);
        assert_eq!(d.levels.len(), 5);
        let s: f64 = d.fractions.iter().sum();
        assert!((s - 1.0).abs() < 1e-9);
        assert!(d.fractions.iter().all(|&f| f > 0.01), "{:?}", d.fractions);
    }

    #[test]
    fn avg_bits_between_extremes() {
        let r = RouterSim::paper_default("LLaMA 3.1 8B");
        let d = r.simulate(Dtype::Bf16, 500, 64, 2);
        let b = d.avg_bits();
        assert!(b > 4.0 && b < 16.0, "avg={b}");
        // byte-rounded is never below bit-exact
        assert!(d.avg_byte_bits() >= b);
        // and strictly above for a menu containing FP12/FP6
        assert!(d.avg_byte_bits() > b + 0.5);
    }

    #[test]
    fn spikier_router_uses_more_top_precision() {
        let base = RouterSim::paper_default("LLaMA 3.1 8B");
        let spiky = RouterSim::paper_default("Mixtral 8x7B");
        let db = base.simulate(Dtype::Bf16, 2000, 32, 3);
        let ds = spiky.simulate(Dtype::Bf16, 2000, 32, 3);
        assert!(
            ds.fractions[0] > db.fractions[0],
            "spiky {:?} vs base {:?}",
            ds.fractions[0],
            db.fractions[0]
        );
    }

    #[test]
    fn int4_menu_distribution() {
        let r = RouterSim::paper_default("LLaMA 3.1 8B");
        let d = r.simulate(Dtype::Int4, 500, 64, 4);
        assert_eq!(d.levels, vec![Dtype::Int4, Dtype::Int2]);
        let s: f64 = d.fractions.iter().sum();
        assert!((s - 1.0).abs() < 1e-9);
        assert!(d.avg_bits() > 2.0 && d.avg_bits() < 4.0);
    }

    #[test]
    fn deterministic_given_seed() {
        let r = RouterSim::paper_default("x");
        let a = r.simulate(Dtype::Bf16, 100, 16, 9);
        let b = r.simulate(Dtype::Bf16, 100, 16, 9);
        assert_eq!(a.fractions, b.fractions);
    }
}
