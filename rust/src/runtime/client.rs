//! PJRT runtime: load AOT'd HLO-text artifacts and execute them on the
//! CPU client (the `xla` crate, xla_extension 0.5.1).
//!
//! Python is never on this path — artifacts are produced once by
//! `make artifacts` and the Rust binary is self-contained afterwards.

use std::path::{Path, PathBuf};

/// Shared PJRT client + artifact directory.
pub struct Runtime {
    pub client: xla::PjRtClient,
    pub artifacts_dir: PathBuf,
}

/// A compiled executable with buffer-based I/O helpers.
pub struct Exe {
    inner: xla::PjRtLoadedExecutable,
    pub name: String,
}

impl Runtime {
    /// Create a CPU runtime rooted at `artifacts_dir`.
    pub fn cpu(artifacts_dir: impl AsRef<Path>) -> anyhow::Result<Self> {
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow::anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(Self {
            client,
            artifacts_dir: artifacts_dir.as_ref().to_path_buf(),
        })
    }

    /// Load + compile an HLO text artifact by file name.
    pub fn load(&self, name: &str) -> anyhow::Result<Exe> {
        let path = self.artifacts_dir.join(name);
        anyhow::ensure!(
            path.exists(),
            "artifact {} missing — run `make artifacts`",
            path.display()
        );
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().expect("utf-8 path"),
        )
        .map_err(|e| anyhow::anyhow!("parse {}: {e:?}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow::anyhow!("compile {}: {e:?}", path.display()))?;
        Ok(Exe {
            inner: exe,
            name: name.to_string(),
        })
    }

    /// Upload a host f32 tensor to a device buffer.
    pub fn buf_f32(&self, data: &[f32], dims: &[usize]) -> anyhow::Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(data, dims, None)
            .map_err(|e| anyhow::anyhow!("upload f32: {e:?}"))
    }

    /// Upload a host i32 scalar.
    pub fn buf_i32_scalar(&self, v: i32) -> anyhow::Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(&[v], &[], None)
            .map_err(|e| anyhow::anyhow!("upload i32: {e:?}"))
    }

    /// Upload a host u16 tensor.
    pub fn buf_u16(&self, data: &[u16], dims: &[usize]) -> anyhow::Result<xla::PjRtBuffer> {
        self.client
            .buffer_from_host_buffer(data, dims, None)
            .map_err(|e| anyhow::anyhow!("upload u16: {e:?}"))
    }
}

impl Exe {
    /// Execute on device buffers and untuple the result. All our entry
    /// points are lowered with `return_tuple=True`, so the single output
    /// buffer holds a tuple literal; we download it once and decompose it
    /// into per-element literals.
    pub fn run(&self, args: &[&xla::PjRtBuffer]) -> anyhow::Result<Vec<xla::Literal>> {
        let outs = self
            .inner
            .execute_b(args)
            .map_err(|e| anyhow::anyhow!("{}: execute: {e:?}", self.name))?;
        anyhow::ensure!(
            !outs.is_empty() && !outs[0].is_empty(),
            "{}: no replica output",
            self.name
        );
        let lit = outs[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("{}: download: {e:?}", self.name))?;
        lit.to_tuple()
            .map_err(|e| anyhow::anyhow!("{}: untuple: {e:?}", self.name))
    }

    /// Execute with literal inputs (slow path, used by tests).
    pub fn run_literals(&self, args: &[xla::Literal]) -> anyhow::Result<Vec<xla::Literal>> {
        let outs = self
            .inner
            .execute::<xla::Literal>(args)
            .map_err(|e| anyhow::anyhow!("{}: execute: {e:?}", self.name))?;
        anyhow::ensure!(
            !outs.is_empty() && !outs[0].is_empty(),
            "{}: no replica output",
            self.name
        );
        let lit = outs[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow::anyhow!("{}: download: {e:?}", self.name))?;
        lit.to_tuple()
            .map_err(|e| anyhow::anyhow!("{}: untuple: {e:?}", self.name))
    }
}

/// Extract a host f32 vec from a tuple element literal.
pub fn to_f32(lit: &xla::Literal) -> anyhow::Result<Vec<f32>> {
    lit.to_vec::<f32>()
        .map_err(|e| anyhow::anyhow!("to_vec f32: {e:?}"))
}

/// Extract a host u16 vec from a tuple element literal.
pub fn to_u16(lit: &xla::Literal) -> anyhow::Result<Vec<u16>> {
    lit.to_vec::<u16>()
        .map_err(|e| anyhow::anyhow!("to_vec u16: {e:?}"))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts() -> Option<Runtime> {
        let dir = std::path::Path::new("artifacts");
        if dir.join("bitplane_pack.hlo.txt").exists() {
            Runtime::cpu(dir).ok()
        } else {
            None
        }
    }

    #[test]
    fn bitplane_pack_artifact_matches_rust_substrate() {
        // The AOT'd L1 Pallas kernel and the Rust bitplane substrate must
        // agree bit-for-bit — this is the L1↔L3 interop contract.
        let Some(rt) = artifacts() else { return };
        let exe = rt.load("bitplane_pack.hlo.txt").unwrap();
        let mut rng = crate::util::rng::Xoshiro256::new(42);
        let codes: Vec<u16> = (0..8192).map(|_| rng.next_u64() as u16).collect();
        let buf = rt.buf_u16(&codes, &[8192]).unwrap();
        let outs = exe.run(&[&buf]).unwrap();
        let planes_flat = outs[0].to_vec::<u8>().unwrap();
        assert_eq!(planes_flat.len(), 16 * 1024);
        let pb = crate::bitplane::disaggregate(crate::fmt::Dtype::Bf16, &codes);
        for p in 0..16 {
            assert_eq!(
                &planes_flat[p * 1024..(p + 1) * 1024],
                pb.plane(p),
                "plane {p}"
            );
        }
    }

    #[test]
    fn exp_delta_artifact_matches_rust_substrate() {
        let Some(rt) = artifacts() else { return };
        let exe = rt.load("exp_delta.hlo.txt").unwrap();
        // meta.json: kv_channels x 16 tokens
        let meta = std::fs::read_to_string("artifacts/meta.json").unwrap();
        let j = crate::report::json::Json::parse(&meta).unwrap();
        let channels = j.get("model").unwrap().get("kv_channels").unwrap().as_usize().unwrap();
        let tokens = 16usize;
        let mut rng = crate::util::rng::Xoshiro256::new(7);
        let cm: Vec<u16> = (0..channels * tokens).map(|_| rng.next_u64() as u16).collect();
        let buf = rt.buf_u16(&cm, &[channels, tokens]).unwrap();
        let outs = exe.run(&[&buf]).unwrap();
        let transformed = to_u16(&outs[0]).unwrap();
        let betas = to_u16(&outs[1]).unwrap();
        let (want_t, want_b) = crate::kvcluster::decorrelate(
            crate::fmt::Dtype::Bf16,
            tokens,
            channels,
            &cm,
            crate::kvcluster::DecorrelateMode::ExpDelta,
        );
        assert_eq!(transformed, want_t);
        assert_eq!(betas, want_b);
    }

    #[test]
    fn missing_artifact_is_a_clean_error() {
        let Some(rt) = artifacts() else { return };
        let err = match rt.load("nope.hlo.txt") {
            Err(e) => e.to_string(),
            Ok(_) => panic!("load of missing artifact succeeded"),
        };
        assert!(err.contains("make artifacts"), "{err}");
    }
}
