//! tinylm model driver: loads weights + HLO artifacts and runs prefill /
//! decode from Rust, with the KV cache held host-side so the coordinator
//! can route it through the memory controller and apply dynamic-
//! quantization policies between steps.

use std::path::Path;

use super::camt::{read_camt, TensorData};
use super::client::{to_f32, Exe, Runtime};
use crate::report::json::Json;

/// Model metadata parsed from artifacts/meta.json.
#[derive(Debug, Clone)]
pub struct ModelMeta {
    pub vocab: usize,
    pub layers: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub n_kv_heads: usize,
    pub d_head: usize,
    pub max_seq: usize,
    pub kv_channels: usize,
    pub prefill_len: usize,
    pub page_tokens: usize,
    pub n_pages: usize,
    pub param_names: Vec<String>,
}

impl ModelMeta {
    pub fn load(dir: &Path) -> anyhow::Result<Self> {
        let text = std::fs::read_to_string(dir.join("meta.json"))
            .map_err(|e| anyhow::anyhow!("meta.json: {e} — run `make artifacts`"))?;
        let j = Json::parse(&text)?;
        let m = j.get("model").ok_or_else(|| anyhow::anyhow!("meta: no model"))?;
        let u = |k: &str| -> anyhow::Result<usize> {
            m.get(k)
                .and_then(Json::as_usize)
                .ok_or_else(|| anyhow::anyhow!("meta: missing model.{k}"))
        };
        Ok(Self {
            vocab: u("vocab")?,
            layers: u("layers")?,
            d_model: u("d_model")?,
            n_heads: u("n_heads")?,
            n_kv_heads: u("n_kv_heads")?,
            d_head: u("d_head")?,
            max_seq: u("max_seq")?,
            kv_channels: u("kv_channels")?,
            prefill_len: j
                .get("prefill_len")
                .and_then(Json::as_usize)
                .ok_or_else(|| anyhow::anyhow!("meta: prefill_len"))?,
            page_tokens: j.get("page_tokens").and_then(Json::as_usize).unwrap_or(16),
            n_pages: j
                .get("n_pages")
                .and_then(Json::as_usize)
                .ok_or_else(|| anyhow::anyhow!("meta: n_pages"))?,
            param_names: j
                .get("params")
                .and_then(Json::as_arr)
                .ok_or_else(|| anyhow::anyhow!("meta: params"))?
                .iter()
                .filter_map(|p| p.get("name").and_then(Json::as_str).map(String::from))
                .collect(),
        })
    }

    /// KV cache element count per full cache tensor.
    pub fn kv_elems(&self) -> usize {
        self.layers * self.max_seq * self.n_kv_heads * self.d_head
    }

    pub fn kv_dims(&self) -> [usize; 4] {
        [self.layers, self.max_seq, self.n_kv_heads, self.d_head]
    }
}

/// Host-side KV cache + decode state for one sequence.
pub struct KvState {
    pub k: Vec<f32>,
    pub v: Vec<f32>,
    /// Last step's per-layer queries, f32[L, H, Dh] (for page scoring).
    pub queries: Vec<f32>,
    pub pos: usize,
}

impl KvState {
    pub fn new(meta: &ModelMeta) -> Self {
        Self {
            k: vec![0.0; meta.kv_elems()],
            v: vec![0.0; meta.kv_elems()],
            queries: vec![0.0; meta.layers * meta.n_heads * meta.d_head],
            pos: 0,
        }
    }
}

/// The loaded model: weights uploaded once as device buffers; prefill and
/// decode executables compiled once.
pub struct TinyLm {
    pub meta: ModelMeta,
    rt: Runtime,
    decode: Exe,
    prefill: Exe,
    params: Vec<xla::PjRtBuffer>,
    /// Host copies of the weights (the memory-controller experiments need
    /// the raw tensors).
    pub host_params: Vec<(String, Vec<f32>, Vec<usize>)>,
}

impl TinyLm {
    /// Load everything from the artifacts directory.
    pub fn load(dir: impl AsRef<Path>) -> anyhow::Result<Self> {
        let dir = dir.as_ref();
        let meta = ModelMeta::load(dir)?;
        let rt = Runtime::cpu(dir)?;
        let decode = rt.load("decode_step.hlo.txt")?;
        let prefill = rt.load("prefill.hlo.txt")?;
        let tensors = read_camt(&dir.join("weights.camt"))?;
        anyhow::ensure!(
            tensors.len() == meta.param_names.len(),
            "weights.camt has {} tensors, meta expects {}",
            tensors.len(),
            meta.param_names.len()
        );
        let mut params = Vec::with_capacity(tensors.len());
        let mut host_params = Vec::with_capacity(tensors.len());
        for (t, want) in tensors.into_iter().zip(&meta.param_names) {
            anyhow::ensure!(&t.name == want, "param order: {} vs {want}", t.name);
            let data = match t.data {
                TensorData::F32(v) => v,
                other => anyhow::bail!("{}: expected f32, got {other:?}", t.name),
            };
            params.push(rt.buf_f32(&data, &t.shape)?);
            host_params.push((t.name, data, t.shape));
        }
        Ok(Self {
            meta,
            rt,
            decode,
            prefill,
            params,
            host_params,
        })
    }

    pub fn runtime(&self) -> &Runtime {
        &self.rt
    }

    /// Run prefill over `tokens` (must equal meta.prefill_len). Returns
    /// (per-position logits, initialized KvState).
    pub fn prefill(&self, tokens: &[u16]) -> anyhow::Result<(Vec<f32>, KvState)> {
        anyhow::ensure!(
            tokens.len() == self.meta.prefill_len,
            "prefill expects {} tokens",
            self.meta.prefill_len
        );
        let toks: Vec<i32> = tokens.iter().map(|&t| t as i32).collect();
        let tbuf = self
            .rt
            .client
            .buffer_from_host_buffer(&toks, &[toks.len()], None)
            .map_err(|e| anyhow::anyhow!("upload tokens: {e:?}"))?;
        let mut args: Vec<&xla::PjRtBuffer> = self.params.iter().collect();
        args.push(&tbuf);
        let outs = self.prefill.run(&args)?;
        let logits = to_f32(&outs[0])?;
        let mut kv = KvState::new(&self.meta);
        kv.k = to_f32(&outs[1])?;
        kv.v = to_f32(&outs[2])?;
        kv.pos = tokens.len();
        Ok((logits, kv))
    }

    /// One decode step at `kv.pos` with an explicit page mask (0 = attend,
    /// -1e9 = skip). Updates `kv` in place (including queries) and returns
    /// the logits for the *next* token.
    pub fn decode_step(
        &self,
        kv: &mut KvState,
        token: u16,
        page_mask: &[f32],
    ) -> anyhow::Result<Vec<f32>> {
        anyhow::ensure!(kv.pos < self.meta.max_seq, "KV cache full");
        let (logits, k, v, queries) =
            self.decode_inner(&kv.k, &kv.v, token, kv.pos, page_mask)?;
        kv.k = k;
        kv.v = v;
        kv.queries = queries;
        kv.pos += 1;
        Ok(logits)
    }

    /// Policy-path decode step: attention reads the *degraded* caches (what
    /// a partial-precision fetch through the memory controller returns),
    /// while the true, losslessly-stored cache `kv` receives the new
    /// token's full-precision K/V. This mirrors the hardware exactly: the
    /// store is lossless; only the *read* is reduced-precision.
    pub fn decode_step_degraded(
        &self,
        kv: &mut KvState,
        degraded_k: &[f32],
        degraded_v: &[f32],
        token: u16,
        page_mask: &[f32],
    ) -> anyhow::Result<Vec<f32>> {
        anyhow::ensure!(kv.pos < self.meta.max_seq, "KV cache full");
        let (logits, k_out, v_out, queries) =
            self.decode_inner(degraded_k, degraded_v, token, kv.pos, page_mask)?;
        // harvest the new token's full-precision K/V into the true cache
        let m = &self.meta;
        let row = m.n_kv_heads * m.d_head;
        for l in 0..m.layers {
            let off = (l * m.max_seq + kv.pos) * row;
            kv.k[off..off + row].copy_from_slice(&k_out[off..off + row]);
            kv.v[off..off + row].copy_from_slice(&v_out[off..off + row]);
        }
        kv.queries = queries;
        kv.pos += 1;
        Ok(logits)
    }

    #[allow(clippy::type_complexity)]
    fn decode_inner(
        &self,
        k_in: &[f32],
        v_in: &[f32],
        token: u16,
        pos: usize,
        page_mask: &[f32],
    ) -> anyhow::Result<(Vec<f32>, Vec<f32>, Vec<f32>, Vec<f32>)> {
        anyhow::ensure!(page_mask.len() == self.meta.n_pages, "page mask arity");
        let dims = self.meta.kv_dims();
        let kbuf = self.rt.buf_f32(k_in, &dims)?;
        let vbuf = self.rt.buf_f32(v_in, &dims)?;
        let tok = self.rt.buf_i32_scalar(token as i32)?;
        let posb = self.rt.buf_i32_scalar(pos as i32)?;
        let mbuf = self.rt.buf_f32(page_mask, &[page_mask.len()])?;
        let mut args: Vec<&xla::PjRtBuffer> = self.params.iter().collect();
        args.extend([&tok, &posb, &kbuf, &vbuf, &mbuf]);
        let outs = self.decode.run(&args)?;
        Ok((
            to_f32(&outs[0])?,
            to_f32(&outs[1])?,
            to_f32(&outs[2])?,
            to_f32(&outs[3])?,
        ))
    }

    /// Greedy argmax helper.
    pub fn argmax(logits: &[f32]) -> u16 {
        let mut best = 0usize;
        for (i, &x) in logits.iter().enumerate() {
            if x > logits[best] {
                best = i;
            }
        }
        best as u16
    }

    /// Negative log-likelihood of `target` under `logits`.
    pub fn nll(logits: &[f32], target: u16) -> f64 {
        let mx = logits.iter().fold(f32::NEG_INFINITY, |m, &x| m.max(x));
        let lse: f64 = logits.iter().map(|&x| ((x - mx) as f64).exp()).sum::<f64>().ln()
            + mx as f64;
        lse - logits[target as usize] as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model() -> Option<TinyLm> {
        let dir = std::path::Path::new("artifacts");
        if dir.join("decode_step.hlo.txt").exists() && dir.join("weights.camt").exists() {
            Some(TinyLm::load(dir).expect("model load"))
        } else {
            None
        }
    }

    #[test]
    fn meta_parses() {
        let dir = std::path::Path::new("artifacts");
        if !dir.join("meta.json").exists() {
            return;
        }
        let m = ModelMeta::load(dir).unwrap();
        assert!(m.vocab >= 2 && m.layers >= 1);
        assert_eq!(m.param_names.len(), 2 + 9 * m.layers);
        assert_eq!(m.kv_channels, m.n_kv_heads * m.d_head);
    }

    #[test]
    fn decode_produces_finite_logits_and_advances() {
        let Some(lm) = model() else { return };
        let mut kv = KvState::new(&lm.meta);
        let mask = vec![0.0f32; lm.meta.n_pages];
        let logits = lm.decode_step(&mut kv, 1, &mask).unwrap();
        assert_eq!(logits.len(), lm.meta.vocab);
        assert!(logits.iter().all(|x| x.is_finite()));
        assert_eq!(kv.pos, 1);
        // the new K entries for position 0 are non-zero
        let written = kv.k.iter().filter(|&&x| x != 0.0).count();
        assert!(written > 0);
    }

    #[test]
    fn trained_model_beats_uniform_on_book_corpus() {
        // End-to-end: the trained weights must predict the synthetic book
        // corpus much better than chance — proof the whole AOT chain
        // (train -> camt -> HLO -> PJRT) preserves the learned model.
        let Some(lm) = model() else { return };
        let toks =
            super::super::camt::read_u16_stream(std::path::Path::new("artifacts/corpus_book.bin"))
                .unwrap();
        let mut kv = KvState::new(&lm.meta);
        let mask = vec![0.0f32; lm.meta.n_pages];
        let n = 96usize;
        let mut nll = 0.0;
        for i in 0..n {
            let logits = lm.decode_step(&mut kv, toks[i], &mask).unwrap();
            nll += TinyLm::nll(&logits, toks[i + 1]);
        }
        let ppl = (nll / n as f64).exp();
        let uniform = lm.meta.vocab as f64;
        assert!(
            ppl < uniform * 0.35,
            "trained ppl {ppl:.1} should be far below uniform {uniform}"
        );
    }

    #[test]
    fn prefill_matches_decode_path() {
        let Some(lm) = model() else { return };
        let toks = super::super::camt::read_u16_stream(std::path::Path::new(
            "artifacts/corpus_wiki.bin",
        ))
        .unwrap();
        let prompt = &toks[..lm.meta.prefill_len];
        let (plogits, pkv) = lm.prefill(prompt).unwrap();
        // decode the same prompt token by token
        let mut kv = KvState::new(&lm.meta);
        let mask = vec![0.0f32; lm.meta.n_pages];
        let mut last = Vec::new();
        for &t in prompt {
            last = lm.decode_step(&mut kv, t, &mask).unwrap();
        }
        let v = lm.meta.vocab;
        let pl = &plogits[(lm.meta.prefill_len - 1) * v..];
        for (a, b) in pl.iter().zip(&last) {
            assert!((a - b).abs() < 3e-3, "prefill {a} vs decode {b}");
        }
        assert_eq!(pkv.pos, lm.meta.prefill_len);
    }
}
