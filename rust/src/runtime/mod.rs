//! Runtime layer: PJRT CPU client wrapper (loads `artifacts/*.hlo.txt`),
//! the `.camt` tensor container reader, and the tinylm model driver.
//! Python never runs on this path.
pub mod camt;
pub mod client;
pub mod model;

pub use camt::{parse_camt, read_camt, read_u16_stream, CamtTensor, TensorData};
pub use client::{to_f32, to_u16, Exe, Runtime};
pub use model::{ModelMeta, TinyLm};
