//! Reader for the `.camt` tensor container written by
//! `python/compile/camt.py` (safetensors substitute). Format documented
//! there; all values little-endian.

use std::io::Read;

/// Tensor payload variants.
#[derive(Debug, Clone, PartialEq)]
pub enum TensorData {
    F32(Vec<f32>),
    U16(Vec<u16>),
    I32(Vec<i32>),
    U8(Vec<u8>),
}

impl TensorData {
    pub fn len(&self) -> usize {
        match self {
            TensorData::F32(v) => v.len(),
            TensorData::U16(v) => v.len(),
            TensorData::I32(v) => v.len(),
            TensorData::U8(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn as_f32(&self) -> Option<&[f32]> {
        match self {
            TensorData::F32(v) => Some(v),
            _ => None,
        }
    }
}

/// A named tensor.
#[derive(Debug, Clone)]
pub struct CamtTensor {
    pub name: String,
    pub shape: Vec<usize>,
    pub data: TensorData,
}

/// Read a .camt file, preserving tensor order.
pub fn read_camt(path: &std::path::Path) -> anyhow::Result<Vec<CamtTensor>> {
    let mut f = std::fs::File::open(path)
        .map_err(|e| anyhow::anyhow!("open {}: {e}", path.display()))?;
    let mut buf = Vec::new();
    f.read_to_end(&mut buf)?;
    parse_camt(&buf)
}

/// Byte cursor over the container.
struct Cur<'a> {
    buf: &'a [u8],
    i: usize,
}

impl<'a> Cur<'a> {
    fn take(&mut self, n: usize) -> anyhow::Result<&'a [u8]> {
        anyhow::ensure!(self.i + n <= self.buf.len(), "camt truncated at {}", self.i);
        let s = &self.buf[self.i..self.i + n];
        self.i += n;
        Ok(s)
    }

    fn u16(&mut self) -> anyhow::Result<u16> {
        Ok(u16::from_le_bytes(self.take(2)?.try_into().unwrap()))
    }

    fn u32(&mut self) -> anyhow::Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
}

/// Parse from bytes.
pub fn parse_camt(buf: &[u8]) -> anyhow::Result<Vec<CamtTensor>> {
    let mut c = Cur { buf, i: 0 };
    anyhow::ensure!(c.take(4)? == b"CAMT", "bad camt magic");
    let version = c.u32()?;
    anyhow::ensure!(version == 1, "unsupported camt version {version}");
    let count = c.u32()? as usize;
    let mut out = Vec::with_capacity(count);
    for _ in 0..count {
        let nlen = c.u16()? as usize;
        let name = String::from_utf8(c.take(nlen)?.to_vec())?;
        let hdr = c.take(2)?;
        let (code, ndim) = (hdr[0], hdr[1] as usize);
        let mut shape = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            shape.push(c.u32()? as usize);
        }
        let n: usize = if ndim == 0 {
            1
        } else {
            shape.iter().product()
        };
        let data = match code {
            0 => {
                let raw = c.take(n * 4)?;
                TensorData::F32(
                    raw.chunks_exact(4)
                        .map(|b| f32::from_le_bytes(b.try_into().unwrap()))
                        .collect(),
                )
            }
            1 => {
                let raw = c.take(n * 2)?;
                TensorData::U16(
                    raw.chunks_exact(2)
                        .map(|b| u16::from_le_bytes(b.try_into().unwrap()))
                        .collect(),
                )
            }
            2 => {
                let raw = c.take(n * 4)?;
                TensorData::I32(
                    raw.chunks_exact(4)
                        .map(|b| i32::from_le_bytes(b.try_into().unwrap()))
                        .collect(),
                )
            }
            3 => TensorData::U8(c.take(n)?.to_vec()),
            k => anyhow::bail!("bad camt dtype code {k}"),
        };
        out.push(CamtTensor { name, shape, data });
    }
    anyhow::ensure!(c.i == buf.len(), "camt trailing bytes");
    Ok(out)
}

/// Read a raw uint16-LE token stream (corpus files).
pub fn read_u16_stream(path: &std::path::Path) -> anyhow::Result<Vec<u16>> {
    let buf = std::fs::read(path)
        .map_err(|e| anyhow::anyhow!("open {}: {e}", path.display()))?;
    anyhow::ensure!(buf.len() % 2 == 0, "odd token file length");
    Ok(buf
        .chunks_exact(2)
        .map(|c| u16::from_le_bytes(c.try_into().unwrap()))
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Build a camt blob by hand (mirrors the python writer).
    fn blob() -> Vec<u8> {
        let mut b = Vec::new();
        b.extend_from_slice(b"CAMT");
        b.extend_from_slice(&1u32.to_le_bytes());
        b.extend_from_slice(&2u32.to_le_bytes());
        // tensor "w": f32 [2,2]
        b.extend_from_slice(&1u16.to_le_bytes());
        b.push(b'w');
        b.push(0); // f32
        b.push(2); // ndim
        b.extend_from_slice(&2u32.to_le_bytes());
        b.extend_from_slice(&2u32.to_le_bytes());
        for x in [1.0f32, -2.5, 3.25, 0.0] {
            b.extend_from_slice(&x.to_le_bytes());
        }
        // tensor "t": u16 scalar-ish [3]
        b.extend_from_slice(&1u16.to_le_bytes());
        b.push(b't');
        b.push(1); // u16
        b.push(1);
        b.extend_from_slice(&3u32.to_le_bytes());
        for x in [7u16, 8, 9] {
            b.extend_from_slice(&x.to_le_bytes());
        }
        b
    }

    #[test]
    fn parse_handwritten_blob() {
        let ts = parse_camt(&blob()).unwrap();
        assert_eq!(ts.len(), 2);
        assert_eq!(ts[0].name, "w");
        assert_eq!(ts[0].shape, vec![2, 2]);
        assert_eq!(ts[0].data, TensorData::F32(vec![1.0, -2.5, 3.25, 0.0]));
        assert_eq!(ts[1].name, "t");
        assert_eq!(ts[1].data, TensorData::U16(vec![7, 8, 9]));
    }

    #[test]
    fn rejects_corruption() {
        let b = blob();
        assert!(parse_camt(&b[..b.len() - 1]).is_err());
        let mut bad = b.clone();
        bad[0] = b'X';
        assert!(parse_camt(&bad).is_err());
        let mut extra = b.clone();
        extra.push(0);
        assert!(parse_camt(&extra).is_err());
    }

    #[test]
    fn reads_real_weights_if_present() {
        let p = std::path::Path::new("artifacts/weights.camt");
        if !p.exists() {
            return; // artifacts not built in this environment
        }
        let ts = read_camt(p).unwrap();
        assert!(ts.iter().any(|t| t.name == "embed"));
        assert!(ts.iter().all(|t| !t.data.is_empty()));
    }
}
