//! Sharing-parity differential suite for content-addressed page sharing
//! (`SchedConfig::sharing`): on a prefix-free workload a sharing-on
//! serve must be **bit-identical** to sharing-off — responses, tokens,
//! read digests, stored-frame digests, schedule events, every fetch
//! metric, and the full flight-recording digests — across codecs ×
//! {1, 8, 32} lanes × fetch modes × prefetch on/off, under a budget
//! tight enough to engage the pressure clamp and force evict/resume
//! cycles. Dedup only ever changes which *physical* frames back a page,
//! never an address, a byte read, or a scheduling decision.
//!
//! On prefix-heavy mixes the payoff side is pinned as a property:
//! random shared-prefix workloads never serve *fewer* sequences with
//! sharing enabled at equal compressed budget. The refcount machinery
//! itself is pinned by a random-lifecycle conservation property:
//! sharer counts always equal the references the live stores hold, no
//! frame frees while referenced, charges sum to the physical unique
//! bytes, and every entry frees exactly once.

use std::cell::Cell;
use std::sync::{Arc, Mutex};

use camc::compress::Codec;
use camc::coordinator::{
    serve_trace, EventKind, FetchMode, KvPageStore, PageIndex, SchedConfig, SchedOutcome,
    ServeMetrics, TrafficResponse,
};
use camc::engine::LaneArray;
use camc::memctrl::Layout;
use camc::obs::RecorderCfg;
use camc::quant::policy::KvPolicy;
use camc::runtime::model::{KvState, ModelMeta};
use camc::util::check::check;
use camc::util::rng::Xoshiro256;
use camc::workload::arrival::ArrivalProcess;
use camc::workload::lengths::LengthDist;
use camc::workload::synthmodel::SynthLm;
use camc::workload::tenant::{PrefixFamily, TenantSpec, WorkloadSpec};
use camc::workload::trace::Trace;

/// Prefix-free reference workload: uniform random prompts never collide
/// on a full 16-token page, so sharing-on must be a pure no-op.
fn dense_spec(n: usize, rate: f64, prompt: usize, output: usize) -> WorkloadSpec {
    WorkloadSpec {
        arrival: ArrivalProcess::Poisson { rate },
        tenants: vec![TenantSpec {
            name: "t".into(),
            weight: 1.0,
            policy: KvPolicy::Full,
            prompt: LengthDist::Fixed(prompt),
            output: LengthDist::Fixed(output),
        }],
        n_requests: n,
        vocab: 256,
        max_seq: 128,
        shared_prefixes: vec![],
    }
}

/// Prefix-heavy mix: one family whose 32-token prefix covers the whole
/// prompt range, so members' finalized pages dedup across requests.
fn prefix_spec(n: usize, rate: f64, prob: u32, fam_seed: u64) -> WorkloadSpec {
    WorkloadSpec {
        arrival: ArrivalProcess::Poisson { rate },
        tenants: vec![TenantSpec {
            name: "chat".into(),
            weight: 1.0,
            policy: KvPolicy::Full,
            prompt: LengthDist::Uniform { lo: 16, hi: 32 },
            output: LengthDist::Uniform { lo: 8, hi: 24 },
        }],
        n_requests: n,
        vocab: 256,
        max_seq: 128,
        shared_prefixes: vec![PrefixFamily {
            tenant: 0,
            tokens: 32,
            prob,
            seed: fam_seed,
        }],
    }
}

/// Everything deterministic about a response (wall time excluded).
fn key(r: &TrafficResponse) -> (u64, Vec<u16>, u64, u64, u64, u64, u32, u64) {
    (
        r.id,
        r.tokens.clone(),
        r.mean_nll.to_bits(),
        r.kv_fetched_bytes,
        r.kv_pages_digest,
        r.read_digest,
        r.evictions,
        r.recovered_faults,
    )
}

fn serve(
    lm: &SynthLm,
    trace: &Trace,
    cfg: &SchedConfig,
    lanes: usize,
) -> (SchedOutcome, ServeMetrics) {
    let la = Arc::new(LaneArray::new(lanes));
    let mut m = ServeMetrics::default();
    let cfg = SchedConfig { collect_digests: true, ..cfg.clone() };
    let out = serve_trace(lm, trace, &cfg, la, &mut m).expect("serve_trace");
    (out, m)
}

/// The integer-domain halves of both runs must match exactly (including
/// the prefetch counters — both runs share the prefetch setting); the
/// f64 latency sums tolerate last-bit merge-order drift only.
fn assert_serve_identical(
    tag: &str,
    off: &(SchedOutcome, ServeMetrics),
    on: &(SchedOutcome, ServeMetrics),
) {
    let ((base, bm), (o, m)) = (off, on);
    assert_eq!(o.events, base.events, "{tag}: schedule diverged");
    assert_eq!(o.peak_active, base.peak_active, "{tag}");
    assert_eq!(o.steps, base.steps, "{tag}");
    assert_eq!(o.pressure_steps, base.pressure_steps, "{tag}");
    assert_eq!(
        o.responses.iter().map(key).collect::<Vec<_>>(),
        base.responses.iter().map(key).collect::<Vec<_>>(),
        "{tag}: responses diverged"
    );
    assert_eq!(m.steps, bm.steps, "{tag}");
    assert_eq!(m.fetched_bytes, bm.fetched_bytes, "{tag}: fetched bytes");
    assert_eq!(m.fetch_frames, bm.fetch_frames, "{tag}: fetched frames");
    assert_eq!(m.fetch_dispatches, bm.fetch_dispatches, "{tag}: dispatches");
    assert_eq!(m.host_copy_bytes, bm.host_copy_bytes, "{tag}: host copies");
    assert_eq!(m.tenants, bm.tenants, "{tag}: per-tenant stats");
    assert_eq!(m.fetch_latency_steps, bm.fetch_latency_steps, "{tag}");
    assert_eq!(m.prefetch_issued, bm.prefetch_issued, "{tag}: prefetch issued");
    assert_eq!(m.prefetch_hits, bm.prefetch_hits, "{tag}: prefetch hits");
    assert_eq!(m.prefetch_misses, bm.prefetch_misses, "{tag}: prefetch misses");
    assert_eq!(
        m.prefetch_wasted_bytes, bm.prefetch_wasted_bytes,
        "{tag}: prefetch waste"
    );
    let rel = (m.sync_fetch_ns - bm.sync_fetch_ns).abs() / bm.sync_fetch_ns.max(1.0);
    assert!(
        rel < 1e-9,
        "{tag}: modeled sync latency drifted: {} vs {}",
        m.sync_fetch_ns,
        bm.sync_fetch_ns
    );
}

#[test]
fn sharing_is_bit_identical_on_prefix_free_traffic() {
    // The acceptance matrix: with a budget tight enough to clamp AND
    // force evict/resume cycles (pinned non-vacuous below), sharing-on
    // equals sharing-off bit-for-bit at every codec, fetch mode, lane
    // count, and prefetch setting — including the flight recording's
    // full and schedule digests — and never finds a single page to
    // dedup on uniform random prompts.
    let lm = SynthLm::tiny(5);
    let trace = Trace::generate(&dense_spec(8, 8.0, 16, 48), 31);
    let budget = 9500u64;
    for codec in [Codec::Zstd, Codec::Lz4] {
        for fetch in [FetchMode::Batched, FetchMode::PerSequence] {
            for prefetch in [false, true] {
                let cfg = SchedConfig {
                    codec,
                    fetch,
                    prefetch,
                    record: Some(RecorderCfg::default()),
                    ..SchedConfig::compressed(budget)
                };
                let base = serve(&lm, &trace, &cfg, 1);
                assert_eq!(base.0.responses.len(), 8, "all requests complete");
                assert!(
                    base.0.events.iter().any(|e| e.kind == EventKind::Evict),
                    "{codec}/{fetch:?}: budget must force evictions or the test is vacuous"
                );
                assert!(
                    base.0.pressure_steps[1] + base.0.pressure_steps[2] > 0,
                    "{codec}/{fetch:?}: budget must engage the pressure clamp"
                );
                for lanes in [1usize, 8, 32] {
                    let scfg = SchedConfig { sharing: true, ..cfg.clone() };
                    let sh = serve(&lm, &trace, &scfg, lanes);
                    let tag = format!("{codec}/{fetch:?}/prefetch={prefetch}/{lanes} lanes");
                    assert_serve_identical(&tag, &base, &sh);
                    // the event-stream witness: recordings digest equal,
                    // both as recorded and as the schedule core (lane
                    // counts never move the digest — pinned elsewhere)
                    let bf = base.0.flight.as_ref().expect("recorder on");
                    let sf = sh.0.flight.as_ref().expect("recorder on");
                    assert_eq!(sf.digest(), bf.digest(), "{tag}: flight digest diverged");
                    assert_eq!(
                        sf.schedule_digest(),
                        bf.schedule_digest(),
                        "{tag}: schedule digest diverged"
                    );
                    let m = &sh.1;
                    assert_eq!(
                        (m.dedup_pages, m.dedup_bytes_saved, m.cow_copies),
                        (0, 0, 0),
                        "{tag}: prefix-free traffic must never dedup"
                    );
                }
            }
        }
    }
}

#[test]
fn sharing_never_serves_fewer_sequences_property() {
    // The payoff property at equal budget: on random shared-prefix
    // workloads, within a fixed virtual-step horizon, sharing-on
    // completes at least as many sequences as sharing-off — charging
    // each sequence only its unique bytes can only free capacity. The
    // accumulated dedup count keeps the property non-vacuous.
    let dedup_total = Cell::new(0u64);
    check("sharing_never_serves_fewer", 12, |g| {
        let lm = SynthLm::tiny(5);
        let n = 8 + g.rng.index(9);
        let rate = 4.0 + g.rng.next_f64() * 6.0;
        let prob = 700 + (g.rng.index(4) as u32) * 100;
        let trace = Trace::generate(&prefix_spec(n, rate, prob, g.case_seed ^ 0xf), g.case_seed);
        let budget = [9500u64, 12 * 1024, 16 * 1024][g.rng.index(3)];
        let horizon = 48 + g.rng.index(5) as u64 * 16;
        let cfg = SchedConfig {
            max_steps: horizon,
            ..SchedConfig::compressed(budget)
        };
        let (off, _) = serve(&lm, &trace, &cfg, 8);
        let on_cfg = SchedConfig { sharing: true, ..cfg.clone() };
        let (on, m) = serve(&lm, &trace, &on_cfg, 8);
        dedup_total.set(dedup_total.get() + m.dedup_pages);
        if on.responses.len() < off.responses.len() {
            return Err(format!(
                "sharing served fewer: {} vs {} (n={n} budget={budget} horizon={horizon} prob={prob})",
                on.responses.len(),
                off.responses.len()
            ));
        }
        Ok(())
    });
    assert!(
        dedup_total.get() > 0,
        "no sampled workload ever deduped a page — the property is vacuous"
    );
}

fn tiny_meta() -> ModelMeta {
    ModelMeta {
        vocab: 256,
        layers: 2,
        d_model: 32,
        n_heads: 4,
        n_kv_heads: 2,
        d_head: 8,
        max_seq: 64,
        kv_channels: 16,
        prefill_len: 32,
        page_tokens: 16,
        n_pages: 4,
        param_names: vec![],
    }
}

fn kv_filled(meta: &ModelMeta, pos: usize, seed: u64) -> KvState {
    let row = meta.n_kv_heads * meta.d_head;
    let mut kv = KvState {
        k: vec![0.0; meta.layers * meta.max_seq * row],
        v: vec![0.0; meta.layers * meta.max_seq * row],
        queries: vec![0.0; meta.layers * meta.n_heads * meta.d_head],
        pos,
    };
    let mut r = Xoshiro256::new(seed);
    for l in 0..meta.layers {
        for t in 0..pos {
            for c in 0..row {
                kv.k[(l * meta.max_seq + t) * row + c] = (r.normal() * 0.5) as f32;
                kv.v[(l * meta.max_seq + t) * row + c] = (r.normal() * 0.5) as f32;
            }
        }
    }
    kv
}

#[test]
fn charged_bytes_sum_to_physical_and_ownership_transfers_on_release() {
    // Two stores share every page: the lowest live request id pays the
    // full stored bytes, the other rides free, the two charges sum to
    // the physical bytes — and when the owner drops, the survivor
    // inherits the bill.
    let meta = tiny_meta();
    let kv = kv_filled(&meta, 16, 3); // one full page, no raw tail
    let index = Arc::new(Mutex::new(PageIndex::default()));
    let lanes = Arc::new(LaneArray::new(2));
    let mk = |seq: u64| {
        let mut s = KvPageStore::with_shared(
            &meta,
            Layout::Proposed,
            Codec::Zstd,
            Arc::clone(&lanes),
        );
        s.attach_sharing(Arc::clone(&index), seq);
        s.sync(&kv, &meta);
        assert_eq!(s.len(), 1);
        s
    };
    let a = mk(1);
    let b = mk(2);
    assert_eq!(index.lock().unwrap().stats().dedup_pages, 1);
    let phys = a.footprint_bytes(&kv);
    assert_eq!(phys, b.footprint_bytes(&kv), "identical content, identical bytes");
    let (ca, sa) = a.charged_footprint_split(&kv);
    let (cb, sb) = b.charged_footprint_split(&kv);
    assert_eq!((ca, sa), (phys, 0), "owner (min id) pays the full page");
    assert_eq!((cb, sb), (0, phys), "the other sharer rides free");
    assert_eq!(ca + cb, phys, "charges sum to the physical bytes");
    drop(a);
    let (cb2, sb2) = b.charged_footprint_split(&kv);
    assert_eq!((cb2, sb2), (phys, 0), "survivor inherits the bill");
    drop(b);
    let ix = index.lock().unwrap();
    assert_eq!(ix.entries(), 0);
    assert_eq!(ix.stats().freed_entries, 1, "last drop frees exactly once");
}

#[test]
fn refcounts_conserve_across_random_lifecycles_property() {
    // Random interleavings of store creation (from a small content pool,
    // so collisions are common) and drops. After EVERY op: the index's
    // sharer count equals the page references the live stores hold, no
    // held entry is ever freed, the charged bytes across stores equal
    // the unique physical bytes, and at the end every entry created was
    // freed exactly once.
    let dedup_total = Cell::new(0u64);
    check("sharing_refcount_conservation", 16, |g| {
        let meta = tiny_meta();
        let lanes = Arc::new(LaneArray::new(4));
        let index = Arc::new(Mutex::new(PageIndex::default()));
        let mut stores: Vec<KvPageStore> = Vec::new();
        let mut next_seq = 0u64;
        let mut created = 0u64;
        for _ in 0..24 {
            if stores.len() < 6 && (stores.is_empty() || g.rng.next_f64() < 0.6) {
                let content = g.rng.index(3) as u64;
                let pos = [16usize, 32][g.rng.index(2)];
                let kv = kv_filled(&meta, pos, 100 + content * 10 + pos as u64);
                let before = index.lock().unwrap().entries();
                let mut s = KvPageStore::with_shared(
                    &meta,
                    Layout::Proposed,
                    Codec::Zstd,
                    Arc::clone(&lanes),
                );
                s.attach_sharing(Arc::clone(&index), next_seq);
                next_seq += 1;
                s.sync(&kv, &meta);
                if s.len() != pos / 16 {
                    return Err(format!("expected {} pages, got {}", pos / 16, s.len()));
                }
                created += (index.lock().unwrap().entries() - before) as u64;
                stores.push(s);
            } else {
                let i = g.rng.index(stores.len());
                stores.swap_remove(i);
            }
            // conservation after every op
            let ix = index.lock().unwrap();
            let mut held: std::collections::BTreeMap<_, u64> = std::collections::BTreeMap::new();
            let mut refs = 0u64;
            for s in &stores {
                for p in 0..s.len() {
                    let Some(k) = s.page_key(p) else {
                        return Err("fault-free page lost its key".into());
                    };
                    if ix.refcount(&k) == 0 || ix.frames(&k).is_none() {
                        return Err("frame freed while still referenced".into());
                    }
                    *held.entry(k).or_insert(0) += 1;
                    refs += 1;
                }
            }
            if ix.total_sharers() != refs {
                return Err(format!(
                    "sharer leak: index counts {}, stores hold {refs}",
                    ix.total_sharers()
                ));
            }
            if ix.entries() != held.len() {
                return Err(format!(
                    "entry leak: {} live entries vs {} held keys",
                    ix.entries(),
                    held.len()
                ));
            }
            for (k, &n) in &held {
                if ix.refcount(k) != n {
                    return Err(format!("refcount {} != holders {n}", ix.refcount(k)));
                }
            }
            drop(ix);
            let charged: u64 = stores.iter().map(|s| s.charged_stored_bytes()).sum();
            let uniq: u64 = held.keys().map(|k| k.len).sum();
            if charged != uniq {
                return Err(format!("charge leak: charged {charged} vs unique {uniq}"));
            }
        }
        dedup_total.set(dedup_total.get() + index.lock().unwrap().stats().dedup_pages);
        stores.clear();
        let ix = index.lock().unwrap();
        if ix.entries() != 0 || ix.total_sharers() != 0 {
            return Err("entries survived their last sharer".into());
        }
        if ix.stats().freed_entries != created {
            return Err(format!(
                "created {created} entries but freed {}",
                ix.stats().freed_entries
            ));
        }
        Ok(())
    });
    assert!(
        dedup_total.get() > 0,
        "content pool never collided — the conservation property is vacuous"
    );
}
