//! Engine determinism contract, end to end: the lane-parallel paths must
//! be byte-identical to the serial ones for every lane count, and the
//! reusable-scratch codec entry points must agree with the one-shot API.
//! Parallelism may change *where* a block runs, never what it produces.
//! Also pins the pooled dispatcher's lifecycle: clean drop with parked
//! workers, and worker panics surfacing at the submitting call site.

use camc::compress::{Codec, CodecScratch};
use camc::engine::{Lane, LaneArray, PAPER_LANES};
use camc::fmt::minifloat::BF16;
use camc::fmt::{CodeTensor, Dtype};
use camc::kvcluster::{compress_groups, decompress_groups, DecorrelateMode, KvGroup};
use camc::memctrl::{Layout, MemController};
use camc::synth::{gen_kv_layer, CorpusProfile};
use camc::util::rng::Xoshiro256;

fn weight_tensor(n: usize, seed: u64) -> CodeTensor {
    let mut r = Xoshiro256::new(seed);
    let codes: Vec<u16> = (0..n)
        .map(|_| BF16.encode((r.normal() * 0.02) as f32) as u16)
        .collect();
    CodeTensor::new(Dtype::Bf16, codes, vec![n])
}

#[test]
fn weight_regions_are_lane_count_invariant() {
    let t = weight_tensor(100_000, 3);
    for codec in [Codec::Lz4, Codec::Zstd] {
        let mut serial = MemController::with_lanes(Layout::Proposed, codec, 1);
        let sid = serial.store_weights("w", &t);
        let serial_frames: Vec<(u64, Vec<u8>)> = serial
            .region(sid)
            .frames()
            .map(|(a, f)| (a, f.to_vec()))
            .collect();
        let (serial_codes, serial_stats) = serial.load(sid, 11, None).unwrap();
        for lanes in [2usize, 4, 8, PAPER_LANES] {
            let mut par = MemController::with_lanes(Layout::Proposed, codec, lanes);
            let pid = par.store_weights("w", &t);
            let par_frames: Vec<(u64, Vec<u8>)> = par
                .region(pid)
                .frames()
                .map(|(a, f)| (a, f.to_vec()))
                .collect();
            assert_eq!(par_frames, serial_frames, "{codec} {lanes} lanes: frames");
            assert_eq!(
                par.region(pid).stored_bytes(),
                serial.region(sid).stored_bytes()
            );
            let (par_codes, par_stats) = par.load(pid, 11, None).unwrap();
            assert_eq!(par_codes, serial_codes, "{codec} {lanes} lanes: load");
            assert_eq!(par_stats.dram_bytes, serial_stats.dram_bytes);
        }
    }
}

#[test]
fn kv_regions_are_lane_count_invariant() {
    let tokens = 300;
    let channels = 96;
    let codes = gen_kv_layer(tokens, channels, CorpusProfile::Book, 0.5, 17);
    let mut serial = MemController::with_lanes(Layout::Proposed, Codec::Zstd, 1);
    let sid = serial.store_kv("kv", Dtype::Bf16, tokens, channels, &codes);
    let serial_frames: Vec<(u64, Vec<u8>)> = serial
        .region(sid)
        .frames()
        .map(|(a, f)| (a, f.to_vec()))
        .collect();
    let (serial_codes, _) = serial.load(sid, 16, None).unwrap();
    assert_eq!(serial_codes, codes, "serial roundtrip");
    for lanes in [2usize, 7, 32] {
        let mut par = MemController::with_lanes(Layout::Proposed, Codec::Zstd, lanes);
        let pid = par.store_kv("kv", Dtype::Bf16, tokens, channels, &codes);
        let par_frames: Vec<(u64, Vec<u8>)> = par
            .region(pid)
            .frames()
            .map(|(a, f)| (a, f.to_vec()))
            .collect();
        assert_eq!(par_frames, serial_frames, "{lanes} lanes: frames");
        let (par_codes, _) = par.load(pid, 16, None).unwrap();
        assert_eq!(par_codes, codes, "{lanes} lanes: roundtrip");
    }
}

#[test]
fn kv_group_batches_are_lane_count_invariant() {
    let groups: Vec<KvGroup> = (0..24)
        .map(|i| {
            let tokens = 16;
            let channels = 64 + (i % 5) * 16;
            let codes = gen_kv_layer(tokens, channels, CorpusProfile::Book, 0.5, 100 + i as u64);
            KvGroup::new(Dtype::Bf16, tokens, channels, codes)
        })
        .collect();
    for mode in [DecorrelateMode::ExpDelta, DecorrelateMode::XorFirst] {
        let serial = compress_groups(&groups, mode, Codec::Zstd, &LaneArray::new(1));
        for lanes in [2usize, 4, 16] {
            let la = LaneArray::new(lanes);
            let par = compress_groups(&groups, mode, Codec::Zstd, &la);
            assert_eq!(par, serial, "{mode:?} {lanes} lanes");
            let back = decompress_groups(&par, &la).unwrap();
            for (kv, b) in groups.iter().zip(&back) {
                assert_eq!(b.codes, kv.codes, "{mode:?} {lanes} lanes roundtrip");
            }
        }
    }
}

#[test]
fn pooled_dispatch_is_byte_identical_at_every_lane_count() {
    // The acceptance sweep: EVERY lane count 1..=PAPER_LANES produces
    // frames byte-identical to the serial controller.
    let t = weight_tensor(20_000, 21);
    let mut serial = MemController::with_lanes(Layout::Proposed, Codec::Zstd, 1);
    let sid = serial.store_weights("w", &t);
    let serial_frames: Vec<(u64, Vec<u8>)> = serial
        .region(sid)
        .frames()
        .map(|(a, f)| (a, f.to_vec()))
        .collect();
    for lanes in 1..=PAPER_LANES {
        let mut par = MemController::with_lanes(Layout::Proposed, Codec::Zstd, lanes);
        let pid = par.store_weights("w", &t);
        let par_frames: Vec<(u64, Vec<u8>)> = par
            .region(pid)
            .frames()
            .map(|(a, f)| (a, f.to_vec()))
            .collect();
        assert_eq!(par_frames, serial_frames, "{lanes} lanes: frames diverged");
    }
}

#[test]
fn pooled_and_spawn_join_dispatch_agree() {
    // The retained spawn/join reference dispatcher and the parked pool
    // must produce identical ordered results over the same lanes.
    let la = LaneArray::new(6);
    let blocks: Vec<Vec<u16>> = (0..40)
        .map(|i| {
            let mut r = Xoshiro256::new(400 + i as u64);
            (0..700).map(|_| r.next_u64() as u16).collect()
        })
        .collect();
    let work = |lane: &mut Lane, codes: &Vec<u16>| {
        let pb = camc::bitplane::layout::disaggregate(Dtype::Bf16, codes);
        let mut payload = Vec::new();
        let dir = lane.compress_planes(&pb, Codec::Zstd, &mut payload);
        (dir, payload)
    };
    assert_eq!(la.run(&blocks, work), la.run_spawn_join(&blocks, work));
}

#[test]
fn pool_drop_is_clean_with_parked_workers() {
    // Drop never-used pools (workers parked from birth) and pools dropped
    // right after batches; neither may hang, leak, or panic.
    for lanes in [2usize, 8, PAPER_LANES] {
        drop(LaneArray::new(lanes));
        let la = LaneArray::new(lanes);
        let items: Vec<u64> = (0..500).collect();
        for _ in 0..4 {
            let out = la.run(&items, |_lane, &x| x ^ 0x5aa5);
            assert_eq!(out.len(), items.len());
        }
        drop(la);
    }
}

#[test]
fn worker_panic_propagates_to_submitting_call_site() {
    let la = LaneArray::new(8);
    let items: Vec<usize> = (0..128).collect();
    let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        la.run(&items, |_lane, &i| {
            if i % 37 == 5 {
                panic!("injected worker panic");
            }
            i
        })
    }));
    assert!(res.is_err(), "worker panic must surface to the submitter");
    // the pool drained and stays usable, and still matches serial output
    let want: Vec<usize> = items.iter().map(|&i| i * 9).collect();
    assert_eq!(la.run(&items, |_lane, &i| i * 9), want);
}

#[test]
fn worker_panic_keeps_its_payload_and_spares_the_default_pool() {
    // The robustness contract for the process-wide pool: a panic inside
    // one batch closure fails exactly that batch's submit site — with the
    // ORIGINAL payload, not a generic "worker panicked" count — and the
    // shared `default_pool()` remains serviceable for every later caller.
    let pool = camc::engine::default_pool();
    let items: Vec<usize> = (0..256).collect();
    let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
        pool.run(&items, |_lane, &i| {
            if i == 77 {
                panic!("original payload {i}");
            }
            i
        })
    }));
    let payload = res.expect_err("worker panic must surface to the submitter");
    let msg = payload
        .downcast_ref::<String>()
        .cloned()
        .or_else(|| payload.downcast_ref::<&str>().map(|s| s.to_string()))
        .expect("panic payload must be the original message");
    assert!(
        msg.contains("original payload 77"),
        "payload must survive verbatim, got: {msg}"
    );
    // the same shared pool serves subsequent batches byte-identically
    let want: Vec<usize> = items.iter().map(|&i| i.wrapping_mul(31)).collect();
    assert_eq!(pool.run(&items, |_lane, &i| i.wrapping_mul(31)), want);
    // a fresh handle (same singleton) is serviceable too
    let again = camc::engine::default_pool();
    assert_eq!(again.run(&items, |_lane, &i| i + 3)[200], 203);
}

#[test]
fn scratch_entry_points_match_oneshot_across_blocks() {
    // One scratch reused across a realistic mixed diet of plane payloads.
    let mut scratch = CodecScratch::new();
    let mut buf = Vec::new();
    let mut r = Xoshiro256::new(9);
    for trial in 0..40 {
        let n = 512 + (trial * 97) % 4096;
        let data: Vec<u8> = match trial % 3 {
            0 => vec![0u8; n],                                  // constant plane
            1 => (0..n).map(|_| r.next_u64() as u8).collect(),  // noise plane
            _ => (0..n)
                .map(|_| {
                    if r.next_f64() < 0.9 {
                        0
                    } else {
                        (r.next_u64() % 16) as u8
                    }
                })
                .collect(), // skewed plane
        };
        for codec in [Codec::Lz4, Codec::Zstd] {
            codec.compress_into(&data, &mut scratch, &mut buf);
            assert_eq!(buf, codec.compress(&data), "{codec} trial {trial}");
            let mut out = Vec::new();
            codec.decompress_append(&buf, data.len(), &mut out).unwrap();
            assert_eq!(out, data, "{codec} trial {trial} roundtrip");
        }
    }
}
