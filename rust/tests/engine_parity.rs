//! Engine determinism contract, end to end: the lane-parallel paths must
//! be byte-identical to the serial ones for every lane count, and the
//! reusable-scratch codec entry points must agree with the one-shot API.
//! Parallelism may change *where* a block runs, never what it produces.

use camc::compress::{Codec, CodecScratch};
use camc::engine::{LaneArray, PAPER_LANES};
use camc::fmt::minifloat::BF16;
use camc::fmt::{CodeTensor, Dtype};
use camc::kvcluster::{compress_groups, decompress_groups, DecorrelateMode, KvGroup};
use camc::memctrl::{Layout, MemController};
use camc::synth::{gen_kv_layer, CorpusProfile};
use camc::util::rng::Xoshiro256;

fn weight_tensor(n: usize, seed: u64) -> CodeTensor {
    let mut r = Xoshiro256::new(seed);
    let codes: Vec<u16> = (0..n)
        .map(|_| BF16.encode((r.normal() * 0.02) as f32) as u16)
        .collect();
    CodeTensor::new(Dtype::Bf16, codes, vec![n])
}

#[test]
fn weight_regions_are_lane_count_invariant() {
    let t = weight_tensor(100_000, 3);
    for codec in [Codec::Lz4, Codec::Zstd] {
        let mut serial = MemController::with_lanes(Layout::Proposed, codec, 1);
        let sid = serial.store_weights("w", &t);
        let serial_frames: Vec<(u64, Vec<u8>)> = serial
            .region(sid)
            .frames()
            .map(|(a, f)| (a, f.to_vec()))
            .collect();
        let (serial_codes, serial_stats) = serial.load(sid, 11, None).unwrap();
        for lanes in [2usize, 4, 8, PAPER_LANES] {
            let mut par = MemController::with_lanes(Layout::Proposed, codec, lanes);
            let pid = par.store_weights("w", &t);
            let par_frames: Vec<(u64, Vec<u8>)> = par
                .region(pid)
                .frames()
                .map(|(a, f)| (a, f.to_vec()))
                .collect();
            assert_eq!(par_frames, serial_frames, "{codec} {lanes} lanes: frames");
            assert_eq!(
                par.region(pid).stored_bytes(),
                serial.region(sid).stored_bytes()
            );
            let (par_codes, par_stats) = par.load(pid, 11, None).unwrap();
            assert_eq!(par_codes, serial_codes, "{codec} {lanes} lanes: load");
            assert_eq!(par_stats.dram_bytes, serial_stats.dram_bytes);
        }
    }
}

#[test]
fn kv_regions_are_lane_count_invariant() {
    let tokens = 300;
    let channels = 96;
    let codes = gen_kv_layer(tokens, channels, CorpusProfile::Book, 0.5, 17);
    let mut serial = MemController::with_lanes(Layout::Proposed, Codec::Zstd, 1);
    let sid = serial.store_kv("kv", Dtype::Bf16, tokens, channels, &codes);
    let serial_frames: Vec<(u64, Vec<u8>)> = serial
        .region(sid)
        .frames()
        .map(|(a, f)| (a, f.to_vec()))
        .collect();
    let (serial_codes, _) = serial.load(sid, 16, None).unwrap();
    assert_eq!(serial_codes, codes, "serial roundtrip");
    for lanes in [2usize, 7, 32] {
        let mut par = MemController::with_lanes(Layout::Proposed, Codec::Zstd, lanes);
        let pid = par.store_kv("kv", Dtype::Bf16, tokens, channels, &codes);
        let par_frames: Vec<(u64, Vec<u8>)> = par
            .region(pid)
            .frames()
            .map(|(a, f)| (a, f.to_vec()))
            .collect();
        assert_eq!(par_frames, serial_frames, "{lanes} lanes: frames");
        let (par_codes, _) = par.load(pid, 16, None).unwrap();
        assert_eq!(par_codes, codes, "{lanes} lanes: roundtrip");
    }
}

#[test]
fn kv_group_batches_are_lane_count_invariant() {
    let groups: Vec<KvGroup> = (0..24)
        .map(|i| {
            let tokens = 16;
            let channels = 64 + (i % 5) * 16;
            let codes = gen_kv_layer(tokens, channels, CorpusProfile::Book, 0.5, 100 + i as u64);
            KvGroup::new(Dtype::Bf16, tokens, channels, codes)
        })
        .collect();
    for mode in [DecorrelateMode::ExpDelta, DecorrelateMode::XorFirst] {
        let serial = compress_groups(&groups, mode, Codec::Zstd, &LaneArray::new(1));
        for lanes in [2usize, 4, 16] {
            let la = LaneArray::new(lanes);
            let par = compress_groups(&groups, mode, Codec::Zstd, &la);
            assert_eq!(par, serial, "{mode:?} {lanes} lanes");
            let back = decompress_groups(&par, &la).unwrap();
            for (kv, b) in groups.iter().zip(&back) {
                assert_eq!(b.codes, kv.codes, "{mode:?} {lanes} lanes roundtrip");
            }
        }
    }
}

#[test]
fn scratch_entry_points_match_oneshot_across_blocks() {
    // One scratch reused across a realistic mixed diet of plane payloads.
    let mut scratch = CodecScratch::new();
    let mut buf = Vec::new();
    let mut r = Xoshiro256::new(9);
    for trial in 0..40 {
        let n = 512 + (trial * 97) % 4096;
        let data: Vec<u8> = match trial % 3 {
            0 => vec![0u8; n],                                  // constant plane
            1 => (0..n).map(|_| r.next_u64() as u8).collect(),  // noise plane
            _ => (0..n)
                .map(|_| if r.next_f64() < 0.9 { 0 } else { (r.next_u64() % 16) as u8 })
                .collect(), // skewed plane
        };
        for codec in [Codec::Lz4, Codec::Zstd] {
            codec.compress_into(&data, &mut scratch, &mut buf);
            assert_eq!(buf, codec.compress(&data), "{codec} trial {trial}");
            let mut out = Vec::new();
            codec.decompress_append(&buf, data.len(), &mut out).unwrap();
            assert_eq!(out, data, "{codec} trial {trial} roundtrip");
        }
    }
}
