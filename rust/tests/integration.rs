//! Cross-module integration tests: substrates composed the way the
//! examples and benches compose them.

use camc::compress::Codec;
use camc::configs::ddr5::DDR5_4800_PAPER;
use camc::configs::{LLAMA31_8B, TINYLM};
use camc::dram::MemorySystem;
use camc::fmt::Dtype;
use camc::memctrl::{Layout, MemController};
use camc::quant::mode::RouterSim;
use camc::quant::traffic::WeightTraffic;
use camc::synth::{encode_checkpoint, gen_kv_layer, sample_checkpoint, CorpusProfile};

#[test]
fn weights_synth_to_controller_to_dram() {
    // synth checkpoint -> controller frames -> timed DRAM fetch, both
    // layouts, partial + full precision — the Fig 10/11 inner loop.
    let ts = sample_checkpoint(&LLAMA31_8B, 1 << 16, 9);
    let t = encode_checkpoint(&ts, Dtype::Bf16);
    let mut results = Vec::new();
    for layout in [Layout::Proposed, Layout::Traditional] {
        let mut mc = MemController::new(layout, Codec::Zstd);
        let id = mc.store_weights("w", &t);
        let mut mem = MemorySystem::new(DDR5_4800_PAPER.clone());
        let (codes, stats) = mc.load(id, 16, Some(&mut mem)).unwrap();
        assert_eq!(codes, t.codes, "{layout:?} lossless");
        results.push((stats.dram_bytes, stats.dram_cycles));
    }
    let (p, t_) = (results[0], results[1]);
    assert!(p.0 < t_.0, "proposed moves fewer bytes");
    assert!(p.1 < t_.1, "proposed finishes sooner");
}

#[test]
fn traffic_model_matches_controller_accounting() {
    // The analytic WeightTraffic model (Figs 10/11) must agree with the
    // functional controller's actual fetch sizes within a few percent.
    let ts = sample_checkpoint(&LLAMA31_8B, 1 << 16, 11);
    let t = encode_checkpoint(&ts, Dtype::Bf16);
    let tr = WeightTraffic::measure(Dtype::Bf16, &t.codes, Codec::Zstd);
    let mut mc = MemController::new(Layout::Proposed, Codec::Zstd);
    let id = mc.store_weights("w", &t);
    for keep in [8u32, 12, 16] {
        let (_, stats) = mc.load(id, keep, None).unwrap();
        let model_bits = tr.p_bits(keep) * t.codes.len() as f64;
        let actual_bits = stats.dram_bytes as f64 * 8.0;
        let rel = (model_bits - actual_bits).abs() / actual_bits;
        assert!(rel < 0.06, "keep={keep}: model {model_bits} vs {actual_bits} ({rel:.3})");
    }
}

#[test]
fn kv_pipeline_end_to_end_synthetic() {
    // KV synth -> clustered frames -> partial read -> exact truncation.
    let (tok, ch) = (64usize, TINYLM.n_kv_heads * TINYLM.d_head());
    let kv = gen_kv_layer(tok, ch, CorpusProfile::Book, 0.3, 21);
    let mut mc = MemController::new(Layout::Proposed, Codec::Zstd);
    let id = mc.store_kv("kv", Dtype::Bf16, tok, ch, &kv);
    let (full, fs) = mc.load(id, 16, None).unwrap();
    assert_eq!(full, kv);
    // Partial KV reads operate on the DELTA-TRANSFORMED planes: keeping
    // the top 9 planes (sign + full exponent field) reconstructs the
    // exact exponent via β + δ; the dropped mantissa floors |x| to its
    // power of two. (Below 9 planes the δ LSB is lost too — the KV
    // policy engine therefore quantizes from the true cache instead;
    // see coordinator::kvmanager.)
    let (p9, hs) = mc.load(id, 9, None).unwrap();
    assert!(hs.dram_bytes < fs.dram_bytes);
    for (a, b) in kv.iter().zip(&p9) {
        // sign + exponent preserved, mantissa zeroed
        assert_eq!(b & 0xFF80, a & 0xFF80, "sign+exp of {a:#06x} vs {b:#06x}");
        assert_eq!(b & 0x007F, 0, "mantissa cleared");
    }
}

#[test]
fn router_to_dram_energy_trend() {
    // Fig 10's trend assembled from the parts: energy(P) < energy(T),
    // and partial-precision routing lowers both.
    let ts = sample_checkpoint(&LLAMA31_8B, 1 << 15, 5);
    let t = encode_checkpoint(&ts, Dtype::Bf16);
    let tr = WeightTraffic::measure(Dtype::Bf16, &t.codes, Codec::Zstd);
    let dist = RouterSim::paper_default("LLaMA 3.1 8B").simulate(Dtype::Bf16, 400, 32, 3);
    let (pb, tb) = tr.avg_bits(&dist);
    let energy = |bits_per_w: f64| {
        let mut mem = MemorySystem::new(DDR5_4800_PAPER.clone());
        let bytes = (1_000_000.0 * bits_per_w / 8.0) as u64;
        mem.run_stream_read(0, bytes);
        mem.stats.energy_pj(&mem.cfg).total_pj()
    };
    let (pe, te) = (energy(pb), energy(tb));
    assert!(pe < te, "P {pe} < T {te}");
    assert!(pe < energy(16.0), "dyn quant < full-precision traffic");
}

#[test]
fn tinylm_serving_with_policies_if_artifacts() {
    // Full L3 serving loop over the real model (skipped when artifacts
    // have not been built).
    if !std::path::Path::new("artifacts/weights.camt").exists() {
        return;
    }
    let lm = camc::runtime::TinyLm::load("artifacts").unwrap();
    let toks =
        camc::runtime::read_u16_stream(std::path::Path::new("artifacts/corpus_wiki.bin"))
            .unwrap();
    let reqs = vec![
        camc::coordinator::Request {
            id: 0,
            prompt: toks[..32].to_vec(),
            max_new_tokens: 8,
            policy: camc::quant::policy::KvPolicy::Full,
        },
        camc::coordinator::Request {
            id: 1,
            prompt: toks[512..544].to_vec(),
            max_new_tokens: 8,
            policy: camc::quant::policy::KvPolicy::QuestTopK { pages: 2 },
        },
    ];
    let mut m = camc::coordinator::ServeMetrics::default();
    let resp = camc::coordinator::serve(&lm, reqs, 2, &mut m).unwrap();
    assert_eq!(resp.len(), 2);
    for r in &resp {
        assert_eq!(r.tokens.len(), 8);
        assert!(r.mean_nll.is_finite());
        assert!(r.kv_ratio > 1.0, "kv pages should compress: {}", r.kv_ratio);
    }
    assert_eq!(m.requests, 2);
}

#[test]
fn tinylm_config_matches_artifacts_meta() {
    if !std::path::Path::new("artifacts/meta.json").exists() {
        return;
    }
    let meta = camc::runtime::model::ModelMeta::load(std::path::Path::new("artifacts")).unwrap();
    assert_eq!(meta.layers, TINYLM.layers);
    assert_eq!(meta.d_model, TINYLM.d_model);
    assert_eq!(meta.n_heads, TINYLM.n_heads);
    assert_eq!(meta.n_kv_heads, TINYLM.n_kv_heads);
    assert_eq!(meta.vocab, TINYLM.vocab);
}

#[test]
fn traffic_trace_through_scheduler_end_to_end() {
    // The whole traffic stack, hermetic: workload spec -> seeded trace ->
    // serialize -> replay -> compressed-budget scheduler on the synthetic
    // backend -> latency/tenant metrics. No artifacts, no XLA.
    use camc::coordinator::{serve_trace, SchedConfig, ServeMetrics};
    use camc::engine::LaneArray;
    use camc::workload::{ArrivalProcess, SynthLm, Trace, WorkloadSpec};
    use std::sync::Arc;

    let spec = WorkloadSpec::chat_plus_batch(
        ArrivalProcess::Bursty {
            burst_rate: 2.0,
            mean_on: 8.0,
            mean_off: 24.0,
        },
        12,
        128,
    );
    let trace = Trace::generate(&spec, 1234);
    // record/replay: the served trace is the deserialized copy
    let replayed = Trace::from_bytes(&trace.to_bytes()).unwrap();
    assert_eq!(trace, replayed);

    let lm = SynthLm::tiny(99);
    let lanes = Arc::new(LaneArray::new(4));
    let mut m = ServeMetrics::default();
    let out = serve_trace(&lm, &replayed, &SchedConfig::compressed(48 * 1024), lanes, &mut m)
        .unwrap();
    assert_eq!(out.responses.len(), 12, "all requests served");
    assert_eq!(m.requests, 12);
    assert!(out.peak_active >= 2, "bursty trace should batch");
    // schedule-domain latency metrics populated and sane
    assert!(m.ttft_steps_p(0.5) >= 1.0);
    assert!(m.e2e_steps_p(0.5) >= m.ttft_steps_p(0.5));
    // tenant accounting covers every request
    assert!(!m.tenants.is_empty());
    assert_eq!(m.tenants.values().map(|t| t.requests).sum::<u64>(), 12);
    assert!(m.tenants.values().all(|t| t.tokens_out > 0));
    // stored pages compress (short chats may finish below one page, so
    // gate on the requests that actually stored pages)
    assert!(out.responses.iter().all(|r| r.kv_ratio >= 1.0));
    assert!(
        out.responses.iter().any(|r| r.kv_ratio > 1.2),
        "at least the long-prompt tenant must store compressed pages"
    );
}
