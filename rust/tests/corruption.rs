//! Corruption-injection tests: flipped or truncated bytes in stored
//! compressed pages, in serialized `Trace` files, and in `CAMCEVT1`
//! flight recordings must surface as clean `Err`s — no panics, no silent
//! wrong data. The stored-frame guarantees rest on the per-plane + header
//! checksums in `memctrl::frame`; the trace and flight-recording
//! guarantees on the trailing FNV-1a digests in `workload::trace` and
//! `obs`.
//!
//! The recovery matrix at the bottom drives the *self-healing* side of
//! the same contract: every `memctrl::fault` class, under every codec ×
//! lane count × parity setting, must resolve on exactly its documented
//! ladder rung (retry / parity repair / plane-prefix salvage /
//! quarantine) with counters identical at every lane count.

use std::sync::{Arc, Mutex};

use camc::compress::Codec;
use camc::coordinator::{
    serve_trace, DecodeArena, KvPageStore, PageIndex, SchedConfig, ServeMetrics, TrafficResponse,
};
use camc::engine::LaneArray;
use camc::memctrl::{FaultClass, FaultPlan, Layout, RegionId, SALVAGE_FLOOR};
use camc::obs::{EventKind, FlightRecording, Recorder, NO_SEQ};
use camc::quant::policy::KvPolicy;
use camc::runtime::model::{KvState, ModelMeta};
use camc::util::check::check;
use camc::util::rng::Xoshiro256;
use camc::workload::{
    ArrivalProcess, LengthDist, PrefixFamily, SynthLm, TenantSpec, Trace, WorkloadSpec,
};

fn tiny_meta() -> ModelMeta {
    ModelMeta {
        vocab: 256,
        layers: 2,
        d_model: 32,
        n_heads: 4,
        n_kv_heads: 2,
        d_head: 8,
        max_seq: 64,
        kv_channels: 16,
        prefill_len: 32,
        page_tokens: 16,
        n_pages: 4,
        param_names: vec![],
    }
}

fn kv_filled(meta: &ModelMeta, pos: usize, seed: u64) -> KvState {
    let row = meta.n_kv_heads * meta.d_head;
    let mut kv = KvState {
        k: vec![0.0; meta.layers * meta.max_seq * row],
        v: vec![0.0; meta.layers * meta.max_seq * row],
        queries: vec![0.0; meta.layers * meta.n_heads * meta.d_head],
        pos,
    };
    let mut r = Xoshiro256::new(seed);
    for l in 0..meta.layers {
        for t in 0..pos {
            for c in 0..row {
                kv.k[(l * meta.max_seq + t) * row + c] = (r.normal() * 0.5) as f32;
                kv.v[(l * meta.max_seq + t) * row + c] = (r.normal() * 0.5) as f32;
            }
        }
    }
    kv
}

/// Build a store with pages synced from a filled cache, then corrupt the
/// frames of a *fresh* store built from the same frames via commit_page.
fn store_with_frames(frames: Vec<Vec<u8>>) -> KvPageStore {
    let meta = tiny_meta();
    let mut s = KvPageStore::new(&meta, Layout::Proposed, Codec::Zstd);
    s.commit_page(0, frames);
    s
}

/// The pristine frames of page 0 of a synced store.
fn page0_frames() -> (Vec<Vec<u8>>, Vec<u16>) {
    let meta = tiny_meta();
    let kv = kv_filled(&meta, 16, 3);
    let mut s = KvPageStore::new(&meta, Layout::Proposed, Codec::Zstd);
    s.sync(&kv, &meta);
    assert_eq!(s.len(), 1);
    let frames: Vec<Vec<u8>> = s
        .mc
        .region(camc::memctrl::RegionId(0))
        .frames()
        .map(|(_, f)| f.to_vec())
        .collect();
    let (codes, _) = s.load_page(0).unwrap();
    (frames, codes)
}

#[test]
fn flipped_bytes_in_stored_pages_error_cleanly() {
    // Every single-byte flip in every frame of a stored page must make
    // load_page return a clean error — the checksums guarantee detection
    // of any single corrupted byte, header or payload.
    let (frames, good_codes) = page0_frames();
    assert!(frames.len() > 1, "page should span several group frames");
    for (fi, frame) in frames.iter().enumerate() {
        // sample every byte for the first frame, a stride for the rest
        // (the sweep is O(frame_len * frame_len) work)
        let stride = if fi == 0 { 1 } else { 7 };
        for i in (0..frame.len()).step_by(stride) {
            for mask in [0x01u8, 0x80] {
                let mut bad = frames.clone();
                bad[fi][i] ^= mask;
                // detection layers, in order: field validation (kind/
                // dtype/codec/mode), header-length bound, header checksum,
                // per-plane checksums, and the KV geometry backstop
                // (m % channels != 0 for every channels value these masks
                // can produce from 16, given m = 256) — between them every
                // single-byte flip in these KV frames is caught
                // deterministically, including flips to the two
                // length-determining fields the header checksum alone
                // cannot pin (see the memctrl::frame module docs)
                let mut s = store_with_frames(bad);
                assert!(
                    s.load_page(0).is_err(),
                    "frame {fi} byte {i} flip {mask:#04x} undetected"
                );
            }
        }
    }
    // pristine frames still decode to the same codes
    let mut s = store_with_frames(frames);
    let (codes, _) = s.load_page(0).unwrap();
    assert_eq!(codes, good_codes);
}

#[test]
fn truncated_stored_pages_error_cleanly() {
    let (frames, _) = page0_frames();
    check("page_truncation", 60, |g| {
        let mut bad = frames.clone();
        let fi = g.rng.index(bad.len());
        let cut = g.rng.index(bad[fi].len());
        bad[fi].truncate(cut);
        let mut s = store_with_frames(bad);
        if s.load_page(0).is_ok() {
            return Err(format!("frame {fi} truncated to {cut} parsed"));
        }
        Ok(())
    });
}

fn sample_trace() -> Trace {
    let spec = WorkloadSpec::chat_plus_batch(ArrivalProcess::Poisson { rate: 0.7 }, 12, 128);
    Trace::generate(&spec, 77)
}

#[test]
fn flipped_bytes_in_trace_files_error_cleanly() {
    // The trailing FNV-1a digest makes ANY single-byte flip a clean parse
    // error — a corrupted trace must never silently replay as a workload
    // nobody recorded.
    let bytes = sample_trace().to_bytes();
    for i in 0..bytes.len() {
        for mask in [0x01u8, 0x80] {
            let mut bad = bytes.clone();
            bad[i] ^= mask;
            assert!(
                Trace::from_bytes(&bad).is_err(),
                "trace byte {i} flip {mask:#04x} undetected"
            );
        }
    }
}

#[test]
fn truncated_and_extended_trace_files_error_cleanly() {
    let t = sample_trace();
    let bytes = t.to_bytes();
    for cut in 0..bytes.len() {
        assert!(
            Trace::from_bytes(&bytes[..cut]).is_err(),
            "truncation to {cut} parsed"
        );
    }
    let mut longer = bytes.clone();
    longer.push(0);
    assert!(Trace::from_bytes(&longer).is_err(), "trailing byte undetected");
    // and the pristine bytes still round-trip
    assert_eq!(Trace::from_bytes(&bytes).unwrap(), t);
}

fn family_trace() -> Trace {
    let mut spec = WorkloadSpec::chat_plus_batch(ArrivalProcess::Poisson { rate: 0.7 }, 12, 128);
    spec.shared_prefixes = vec![PrefixFamily {
        tenant: 0,
        tokens: 16,
        prob: 1000,
        seed: 5,
    }];
    Trace::generate(&spec, 77)
}

#[test]
fn family_stamped_traces_roundtrip_and_reject_corruption() {
    // `CAMCTRC3` carries the family column; the digest discipline must
    // be as airtight for family-stamped traces as for plain ones — any
    // flipped or truncated byte is a clean parse error.
    let t = family_trace();
    assert!(
        t.requests.iter().any(|r| r.family == 0),
        "prob 1000 on the majority tenant must stamp members"
    );
    let bytes = t.to_bytes();
    assert_eq!(&bytes[..8], b"CAMCTRC3");
    assert_eq!(Trace::from_bytes(&bytes).unwrap(), t);
    for i in 0..bytes.len() {
        for mask in [0x01u8, 0x80] {
            let mut bad = bytes.clone();
            bad[i] ^= mask;
            assert!(
                Trace::from_bytes(&bad).is_err(),
                "family trace byte {i} flip {mask:#04x} undetected"
            );
        }
    }
    for cut in 0..bytes.len() {
        assert!(
            Trace::from_bytes(&bytes[..cut]).is_err(),
            "family trace truncated to {cut} parsed"
        );
    }
}

/// One synced single-page store (pos 16 = exactly one stored page, no raw
/// tail) on an isolated `lanes`-wide pool, parity set before the sync so
/// the frames carry (or don't carry) the XOR parity plane.
fn synced_store(codec: Codec, lanes: usize, parity: bool) -> KvPageStore {
    let meta = tiny_meta();
    let kv = kv_filled(&meta, 16, 3);
    let mut s = KvPageStore::with_shared(
        &meta,
        Layout::Proposed,
        codec,
        Arc::new(LaneArray::new(lanes)),
    );
    s.mc.parity = parity;
    s.sync(&kv, &meta);
    assert_eq!(s.len(), 1);
    s
}

/// Fault-free reference codes for page 0 at plane prefix `keep`.
fn pristine_codes(codec: Codec, parity: bool, keep: u32) -> Vec<u16> {
    let mut s = synced_store(codec, 1, parity);
    let mut arena = DecodeArena::new();
    let out = s.fetch_pages(&[keep], &mut arena).unwrap();
    assert!(out.quarantine.is_none());
    arena.codes(out.pages[0].1).to_vec()
}

#[test]
fn recovery_matrix_resolves_every_fault_class_on_its_documented_rung() {
    // fault class × codec × {1,8,32} lanes × parity on/off. Each cell
    // must land on exactly one ladder rung, never panic, and produce
    // counters (and codes, where the read survives) identical at every
    // lane count — lanes change where a frame decodes, never what the
    // ladder does.
    let cases: Vec<(&str, FaultPlan)> = vec![
        ("transient", FaultPlan::always(11, FaultClass::Transient)),
        ("lane", FaultPlan::always(12, FaultClass::LaneFault)),
        ("plane-high", {
            let mut p = FaultPlan::always(13, FaultClass::PlaneFlip);
            p.flip_plane = Some(12); // above SALVAGE_FLOOR: salvageable
            p
        }),
        ("plane-low", {
            let mut p = FaultPlan::always(14, FaultClass::PlaneFlip);
            p.flip_plane = Some(1); // below SALVAGE_FLOOR: fatal sans parity
            p
        }),
        ("header", FaultPlan::always(15, FaultClass::HeaderFlip)),
    ];
    for codec in [Codec::Lz4, Codec::Zstd] {
        for parity in [false, true] {
            let full = pristine_codes(codec, parity, 16);
            for (name, plan) in &cases {
                let tag = format!("{codec} parity={parity} {name}");
                let plan = Arc::new(plan.clone());
                let mut baseline: Option<((u64, u64, u64, u64), Option<String>, Option<Vec<u16>>)> =
                    None;
                for lanes in [1usize, 8, 32] {
                    let mut s = synced_store(codec, lanes, parity);
                    s.mc.install_faults(Arc::clone(&plan), 1);
                    let mut arena = DecodeArena::new();
                    let out = s
                        .fetch_pages(&[16], &mut arena)
                        .unwrap_or_else(|e| panic!("{tag} {lanes} lanes: hard error {e}"));
                    let r = &s.mc.recovery;
                    let counters =
                        (r.faults_injected, r.retries, r.parity_repairs, r.salvaged_reads);
                    assert!(r.faults_injected > 0, "{tag}: plan never fired");
                    let codes = if out.quarantine.is_none() {
                        Some(arena.codes(out.pages[0].1).to_vec())
                    } else {
                        assert!(out.pages.is_empty(), "{tag}: quarantined read served data");
                        None
                    };
                    match *name {
                        "transient" | "lane" => {
                            // rung 1: bounded retry clears it; stored bytes
                            // untouched, so the read is byte-pristine
                            assert!(out.quarantine.is_none(), "{tag}: retry rung quarantined");
                            assert!(r.retries >= r.faults_injected, "{tag}: no retries");
                            assert_eq!(r.parity_repairs, 0, "{tag}");
                            assert_eq!(r.salvaged_reads, 0, "{tag}");
                            assert_eq!(codes.as_ref(), Some(&full), "{tag}: codes diverged");
                        }
                        "plane-high" if parity => {
                            // rung 2: every flipped plane healed in place
                            assert!(out.quarantine.is_none(), "{tag}");
                            assert_eq!(r.parity_repairs, r.faults_injected, "{tag}: unhealed");
                            assert_eq!(r.salvaged_reads, 0, "{tag}");
                            assert_eq!(r.retries, 0, "{tag}");
                            assert_eq!(codes.as_ref(), Some(&full), "{tag}: repair not byte-exact");
                            let dk = s.mc.region(RegionId(0)).degraded_keep();
                            assert_eq!(dk, u32::MAX, "{tag}: repair must not degrade");
                        }
                        "plane-high" => {
                            // rung 3: serve the intact prefix, mark the
                            // region degraded-only
                            assert!(out.quarantine.is_none(), "{tag}");
                            assert_eq!(r.salvaged_reads, r.faults_injected, "{tag}: unsalvaged");
                            assert_eq!(r.parity_repairs, 0, "{tag}");
                            let dk = s.mc.region(RegionId(0)).degraded_keep();
                            assert_eq!(dk, 12, "{tag}: salvage must clamp to the flipped plane");
                            assert!(dk >= SALVAGE_FLOOR, "{tag}");
                            let clamped = pristine_codes(codec, parity, dk);
                            assert_eq!(
                                codes.as_ref(),
                                Some(&clamped),
                                "{tag}: salvaged read must equal the pristine clamped view"
                            );
                        }
                        "plane-low" if parity => {
                            // parity turns the fatal low-plane flip into a
                            // rung-2 repair
                            assert!(out.quarantine.is_none(), "{tag}");
                            assert_eq!(r.parity_repairs, r.faults_injected, "{tag}: unhealed");
                            assert_eq!(codes.as_ref(), Some(&full), "{tag}: repair not byte-exact");
                        }
                        "plane-low" => {
                            // rung 4: below the salvage floor nothing milder
                            // helps — the read quarantines, cleanly
                            assert!(out.quarantine.is_some(), "{tag}: expected quarantine");
                            assert_eq!(r.retries, 0, "{tag}");
                            assert_eq!(r.parity_repairs, 0, "{tag}");
                            assert_eq!(r.salvaged_reads, 0, "{tag}");
                        }
                        "header" => {
                            // rung 4 always: parity never covers the header
                            assert!(out.quarantine.is_some(), "{tag}: expected quarantine");
                            assert_eq!(r.retries, 0, "{tag}");
                            assert_eq!(r.parity_repairs, 0, "{tag}");
                            assert_eq!(r.salvaged_reads, 0, "{tag}");
                        }
                        other => unreachable!("unknown case {other}"),
                    }
                    let cell = (counters, out.quarantine.clone(), codes);
                    match &baseline {
                        None => baseline = Some(cell),
                        Some(b) => assert_eq!(
                            b, &cell,
                            "{tag}: outcome diverged between 1 and {lanes} lanes"
                        ),
                    }
                }
            }
        }
    }
}

/// Two stores attached to one `PageIndex`, both synced from the same
/// filled cache — commit-time content addressing dedups their page 0
/// onto one shared frame set (refcount 2).
fn shared_pair(
    codec: Codec,
    parity: bool,
    index: &Arc<Mutex<PageIndex>>,
) -> (KvPageStore, KvPageStore) {
    let meta = tiny_meta();
    let kv = kv_filled(&meta, 16, 3);
    let lanes = Arc::new(LaneArray::new(8));
    let mk = |seq: u64| {
        let mut s = KvPageStore::with_shared(&meta, Layout::Proposed, codec, Arc::clone(&lanes));
        s.mc.parity = parity;
        s.attach_sharing(Arc::clone(index), seq);
        s.sync(&kv, &meta);
        assert_eq!(s.len(), 1);
        s
    };
    let a = mk(1);
    let b = mk(2);
    let ix = index.lock().unwrap();
    assert_eq!(ix.stats().dedup_pages, 1, "second sync must dedup page 0");
    assert_eq!(ix.refcount(&a.page_key(0).unwrap()), 2);
    drop(ix);
    (a, b)
}

#[test]
fn parity_heal_on_shared_frame_repairs_once_for_all_sharers() {
    // Rung 2 on a shared frame: the flip lands on the reader's private
    // CoW copy, parity heals it byte-exactly, and reconcile folds the
    // healed copy back onto the shared frame — a single repair, both
    // sharers read identical bytes, and the entry keeps both sharers
    // (no CoW charged for a fault that left no divergence).
    let index = Arc::new(Mutex::new(PageIndex::default()));
    let (mut a, mut b) = shared_pair(Codec::Zstd, true, &index);
    let key = a.page_key(0).unwrap();
    let mut plan = FaultPlan::always(13, FaultClass::PlaneFlip);
    plan.flip_plane = Some(12);
    a.mc.install_faults(Arc::new(plan), 1);
    let mut arena = DecodeArena::new();
    let out = a.fetch_pages(&[16], &mut arena).unwrap();
    assert!(out.quarantine.is_none(), "parity must heal the flip");
    let healed = arena.codes(out.pages[0].1).to_vec();
    assert!(a.mc.recovery.faults_injected > 0, "plan never fired");
    assert_eq!(
        a.mc.recovery.parity_repairs, a.mc.recovery.faults_injected,
        "every flip must resolve as exactly one parity repair"
    );
    assert_eq!(healed, pristine_codes(Codec::Zstd, true, 16), "repair not byte-exact");
    a.reconcile_sharing();
    {
        let ix = index.lock().unwrap();
        assert_eq!(ix.stats().cow_copies, 0, "heal must not be billed as CoW");
        assert_eq!(ix.refcount(&key), 2, "healed copy re-shares");
    }
    assert_eq!(a.page_key(0), Some(key));
    // the other sharer never saw the fault and reads the same bytes
    let mut arena_b = DecodeArena::new();
    let out_b = b.fetch_pages(&[16], &mut arena_b).unwrap();
    assert!(out_b.quarantine.is_none());
    assert_eq!(b.mc.recovery.faults_injected, 0);
    assert_eq!(arena_b.codes(out_b.pages[0].1).to_vec(), healed);
}

#[test]
fn unhealable_fault_on_shared_frame_quarantines_only_the_faulted_sharer() {
    // Rung 4 on a shared frame: the header flip corrupts the reader's
    // private copy only, so the OTHER sharer keeps serving pristine
    // bytes. Dropping the quarantined store (the scheduler's removal
    // path) releases its refcount without freeing the still-referenced
    // entry; the last drop frees it exactly once.
    let index = Arc::new(Mutex::new(PageIndex::default()));
    let (mut a, mut b) = shared_pair(Codec::Lz4, false, &index);
    let key = b.page_key(0).unwrap();
    a.mc
        .install_faults(Arc::new(FaultPlan::always(15, FaultClass::HeaderFlip)), 1);
    let mut arena = DecodeArena::new();
    let out = a.fetch_pages(&[16], &mut arena).unwrap();
    assert!(out.quarantine.is_some(), "header flip must quarantine the reader");
    drop(a);
    {
        let ix = index.lock().unwrap();
        assert_eq!(ix.refcount(&key), 1, "survivor still holds the entry");
        assert_eq!(ix.stats().freed_entries, 0, "entry must not free while referenced");
        assert_eq!(ix.stats().cow_copies, 0);
    }
    let mut arena_b = DecodeArena::new();
    let out_b = b.fetch_pages(&[16], &mut arena_b).unwrap();
    assert!(out_b.quarantine.is_none(), "survivor must keep serving");
    assert_eq!(
        arena_b.codes(out_b.pages[0].1).to_vec(),
        pristine_codes(Codec::Lz4, false, 16)
    );
    drop(b);
    let ix = index.lock().unwrap();
    assert_eq!(ix.entries(), 0, "last drop frees the entry");
    assert_eq!(ix.stats().freed_entries, 1, "and frees it exactly once");
}

#[test]
fn salvage_on_shared_frame_cow_detaches_only_the_degraded_sharer() {
    // Rung 3 keeps the plane corruption in the reader's copy (reads
    // clamp to the intact prefix) — that is true divergence: reconcile
    // detaches it as a CoW copy exactly once, while the other sharer
    // keeps serving full precision from the shared frame.
    let index = Arc::new(Mutex::new(PageIndex::default()));
    let (mut a, mut b) = shared_pair(Codec::Zstd, false, &index);
    let key = b.page_key(0).unwrap();
    let mut plan = FaultPlan::always(13, FaultClass::PlaneFlip);
    plan.flip_plane = Some(12);
    a.mc.install_faults(Arc::new(plan), 1);
    let mut arena = DecodeArena::new();
    let out = a.fetch_pages(&[16], &mut arena).unwrap();
    assert!(out.quarantine.is_none(), "plane 12 is above the salvage floor");
    assert_eq!(a.mc.recovery.salvaged_reads, a.mc.recovery.faults_injected);
    assert_eq!(a.mc.region(RegionId(0)).degraded_keep(), 12);
    assert_eq!(
        arena.codes(out.pages[0].1).to_vec(),
        pristine_codes(Codec::Zstd, false, 12),
        "salvaged read must equal the pristine clamped view"
    );
    a.reconcile_sharing();
    a.reconcile_sharing(); // divergence copies exactly once: a no-op repeat
    {
        let ix = index.lock().unwrap();
        assert_eq!(ix.stats().cow_copies, 1, "divergence must CoW exactly once");
        assert_eq!(ix.refcount(&key), 1);
    }
    assert_eq!(a.page_key(0), None, "detached page is private now");
    let mut arena_b = DecodeArena::new();
    let out_b = b.fetch_pages(&[16], &mut arena_b).unwrap();
    assert!(out_b.quarantine.is_none());
    assert_eq!(
        arena_b.codes(out_b.pages[0].1).to_vec(),
        pristine_codes(Codec::Zstd, false, 16),
        "the surviving sharer keeps full precision"
    );
}

/// Everything deterministic about a served response (wall time excluded).
fn response_key(r: &TrafficResponse) -> (u64, Vec<u16>, u64, u64, u64, u64, u32, u64) {
    (
        r.id,
        r.tokens.clone(),
        r.mean_nll.to_bits(),
        r.kv_fetched_bytes,
        r.kv_pages_digest,
        r.read_digest,
        r.evictions,
        r.recovered_faults,
    )
}

#[test]
fn speculative_fetch_resolves_faults_exactly_once() {
    // The prefetch engine runs the recovery ladder at *plan* time, one
    // virtual step early (the fault step advances before speculation, so
    // speculative reads take the next step's draws). The synchronous
    // revisit of the same sites must then be a no-op: a full contended
    // serve under an aggressive fault plan — with speculation on, and
    // with chaos forcing discard-and-refetch of speculated regions —
    // counts EXACTLY the recovery actions of the synchronous reference,
    // and serves byte-identical responses. A double-resolved (or
    // skipped) fault site would show up in any of these counters.
    let spec = WorkloadSpec {
        arrival: ArrivalProcess::Poisson { rate: 2.0 },
        tenants: vec![TenantSpec {
            name: "t".into(),
            weight: 1.0,
            policy: KvPolicy::Full,
            prompt: LengthDist::Fixed(16),
            output: LengthDist::Fixed(32),
        }],
        n_requests: 16,
        vocab: 256,
        max_seq: 128,
        shared_prefixes: vec![],
    };
    let trace = Trace::generate(&spec, 23);
    // rates high enough that every rung fires mid-serve (mirrors the
    // scheduler's own fault-determinism test)
    let plan = Arc::new(FaultPlan {
        seed: 77,
        p_plane_flip: 220,
        p_header_flip: 17,
        p_transient: 80,
        p_lane_fault: 40,
        flip_plane: None,
    });
    let serve = |prefetch: bool, chaos: u64, parity: bool| {
        let lm = SynthLm::tiny(9);
        let la = Arc::new(LaneArray::new(8));
        let mut m = ServeMetrics::default();
        let cfg = SchedConfig {
            collect_digests: true,
            parity,
            prefetch,
            prefetch_chaos: chaos,
            faults: Some(Arc::clone(&plan)),
            ..SchedConfig::compressed(1 << 20)
        };
        let out = serve_trace(&lm, &trace, &cfg, la, &mut m).expect("serve_trace");
        (out, m)
    };
    for parity in [false, true] {
        let (base, bm) = serve(false, 0, parity);
        assert!(bm.faults_injected > 0, "parity={parity}: plan never fired");
        assert!(bm.retries > 0, "parity={parity}: no transient faults drawn");
        for chaos in [0u64, 3] {
            let (o, m) = serve(true, chaos, parity);
            let tag = format!("parity={parity}/chaos={chaos}");
            assert_eq!(o.events, base.events, "{tag}: schedule diverged");
            assert_eq!(
                o.responses.iter().map(response_key).collect::<Vec<_>>(),
                base.responses.iter().map(response_key).collect::<Vec<_>>(),
                "{tag}: responses diverged"
            );
            assert_eq!(
                (
                    m.faults_injected,
                    m.retries,
                    m.parity_repairs,
                    m.salvaged_reads,
                    m.quarantined_seqs
                ),
                (
                    bm.faults_injected,
                    bm.retries,
                    bm.parity_repairs,
                    bm.salvaged_reads,
                    bm.quarantined_seqs
                ),
                "{tag}: recovery actions diverged — a fault site resolved \
                 twice (or not at all) across the speculative/synchronous seam"
            );
            assert!(m.prefetch_issued > 0, "{tag}: speculation never armed");
            if chaos > 0 {
                // discarded speculation re-fetched the same sites — the
                // counter identity above proves the revisit was a no-op
                assert!(m.prefetch_wasted_bytes > 0, "{tag}: chaos never discarded");
            }
        }
    }
}

/// A recording exercising every `obs` event tag, both real and
/// run-scoped (`NO_SEQ`) owners, and a nonzero virtual clock — every
/// `CAMCEVT1` encoder branch appears in the byte stream below.
fn sample_recording() -> FlightRecording {
    let mut r = Recorder::new(64);
    r.begin_step(3);
    r.push(7, EventKind::Admit);
    r.push(8, EventKind::Resume);
    r.advance_ps(1250);
    r.push(NO_SEQ, EventKind::FetchDram { bytes: 4096, frames: 9 });
    r.push(NO_SEQ, EventKind::FetchLanes { bytes: 4096, frames: 9 });
    r.push(
        7,
        EventKind::Recovery { faults: 1, retries: 2, parity_repairs: 0, salvaged: 1 },
    );
    r.push(NO_SEQ, EventKind::HostCopy { bytes: 513 });
    r.push(9, EventKind::Quarantine);
    r.push(7, EventKind::Finish);
    r.push(8, EventKind::Evict);
    r.push(NO_SEQ, EventKind::Pressure { level: 2 });
    r.push(7, EventKind::PrefetchIssue { pages: 3, bytes: 768 });
    r.begin_step(4);
    r.push(7, EventKind::PrefetchHit { pages: 2 });
    r.push(7, EventKind::PrefetchMiss { pages: 1 });
    r.push(7, EventKind::PrefetchDiscard { bytes: 256 });
    r.push(8, EventKind::Share { bytes: 2048 });
    r.push(8, EventKind::Unshare { bytes: 2048 });
    r.push(7, EventKind::Cow { bytes: 1024 });
    r.push(NO_SEQ, EventKind::Dropped { count: 11 });
    r.into_recording()
}

#[test]
fn flight_recording_bytes_roundtrip() {
    let rec = sample_recording();
    assert_eq!(rec.events.len(), 19);
    let bytes = rec.to_bytes();
    let back = FlightRecording::from_bytes(&bytes).unwrap();
    assert_eq!(back, rec);
    assert_eq!(back.digest(), rec.digest());
    assert_eq!(back.schedule_digest(), rec.schedule_digest());
    // the advisory records are real, so the two digests split
    assert_ne!(rec.digest(), rec.schedule_digest());
}

#[test]
fn flipped_bytes_in_flight_recordings_error_cleanly() {
    // The trailing FNV-1a digest makes ANY single-byte flip a clean
    // parse error — a corrupted recording must never silently replay as
    // an incident timeline nobody flew.
    let bytes = sample_recording().to_bytes();
    for i in 0..bytes.len() {
        for mask in [0x01u8, 0x80] {
            let mut bad = bytes.clone();
            bad[i] ^= mask;
            assert!(
                FlightRecording::from_bytes(&bad).is_err(),
                "recording byte {i} flip {mask:#04x} undetected"
            );
        }
    }
}

#[test]
fn truncated_and_extended_flight_recordings_error_cleanly() {
    let rec = sample_recording();
    let bytes = rec.to_bytes();
    for cut in 0..bytes.len() {
        assert!(
            FlightRecording::from_bytes(&bytes[..cut]).is_err(),
            "truncation to {cut} parsed"
        );
    }
    let mut longer = bytes.clone();
    longer.push(0);
    assert!(
        FlightRecording::from_bytes(&longer).is_err(),
        "trailing byte undetected"
    );
    // and the pristine bytes still round-trip
    assert_eq!(FlightRecording::from_bytes(&bytes).unwrap(), rec);
}
