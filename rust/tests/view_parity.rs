//! Differential suite for the zero-materialization degrade path: the lazy
//! `KvViewPlan` + `DecodeArena` read (what the serve loop's attention now
//! consumes) must be bit-identical to the materialized `plan`/copy
//! reference — element by element at the accessor level, digest by digest
//! through the synthetic backend's attention readout, and response by
//! response through full contended serves — across codecs × {1, 2, 8, 32}
//! lanes × pressure clamps, including evicted-then-resumed sequences.

use std::sync::Arc;

use camc::compress::Codec;
use camc::coordinator::{
    degrade_f32, materialize_read, serve_trace, span_k_base, span_v_base, DecodeArena, EventKind,
    FetchMode, KvPageStore, KvRead, KvViews, MaterializedRef, PolicyEngine, SchedConfig,
    SchedOutcome, ServeMetrics, StepModel, StepOutput, TrafficResponse,
};
use camc::engine::LaneArray;
use camc::fmt::minifloat::BF16;
use camc::memctrl::Layout;
use camc::quant::policy::{KvPolicy, PageTier};
use camc::runtime::model::{KvState, ModelMeta};
use camc::util::check::check;
use camc::util::rng::Xoshiro256;
use camc::workload::arrival::ArrivalProcess;
use camc::workload::lengths::LengthDist;
use camc::workload::synthmodel::SynthLm;
use camc::workload::tenant::{TenantSpec, WorkloadSpec};
use camc::workload::trace::Trace;

fn tiny_meta() -> ModelMeta {
    ModelMeta {
        vocab: 256,
        layers: 2,
        d_model: 32,
        n_heads: 4,
        n_kv_heads: 2,
        d_head: 8,
        max_seq: 128,
        kv_channels: 16,
        prefill_len: 32,
        page_tokens: 16,
        n_pages: 8,
        param_names: vec![],
    }
}

fn kv_filled(meta: &ModelMeta, pos: usize, seed: u64) -> KvState {
    let row = meta.n_kv_heads * meta.d_head;
    let mut kv = KvState {
        k: vec![0.0; meta.layers * meta.max_seq * row],
        v: vec![0.0; meta.layers * meta.max_seq * row],
        queries: vec![0.0; meta.layers * meta.n_heads * meta.d_head],
        pos,
    };
    let mut r = Xoshiro256::new(seed);
    let scales: Vec<f32> = (0..row).map(|_| 2f32.powf(r.normal() as f32)).collect();
    for l in 0..meta.layers {
        for t in 0..pos {
            for c in 0..row {
                kv.k[(l * meta.max_seq + t) * row + c] =
                    scales[c] * (1.0 + 0.05 * r.normal() as f32);
                kv.v[(l * meta.max_seq + t) * row + c] =
                    scales[c] * (1.0 + 0.05 * r.normal() as f32);
            }
        }
    }
    for q in kv.queries.iter_mut() {
        *q = r.normal() as f32;
    }
    kv
}

#[test]
fn view_values_match_materialized_values_property() {
    // Accessor-level identity: every element the lazy view path can
    // resolve (fetched page codes, degraded working tail) must be
    // bit-identical to the dense copy `materialize_read` builds from the
    // same views — random positions, policies, codecs, pressure clamps.
    check("view_vs_materialized_values", 12, |g| {
        let meta = tiny_meta();
        let codec = if g.rng.next_f64() < 0.5 {
            Codec::Lz4
        } else {
            Codec::Zstd
        };
        let pos = g.usize_in(1, 120);
        let kv = kv_filled(&meta, pos, g.case_seed);
        let policy = match g.rng.index(3) {
            0 => KvPolicy::Full,
            1 => KvPolicy::QuestTopK { pages: 1 + g.rng.index(3) },
            _ => KvPolicy::DynamicQuant {
                tiers: vec![
                    PageTier { pages: 2, dtype: camc::fmt::Dtype::Bf16 },
                    PageTier { pages: 3, dtype: camc::fmt::Dtype::Fp8E4M3 },
                ],
            },
        };
        let clamp = match g.rng.index(3) {
            0 => None,
            1 => Some(8),
            _ => Some(4),
        };
        let engine = PolicyEngine::with_lanes(policy, 1);
        let plan = engine.plan_pressured(&kv, &meta, clamp);
        let mut store = KvPageStore::new(&meta, Layout::Proposed, codec);
        store.sync(&kv, &meta);
        let mut arena = DecodeArena::new();
        let fetch = store
            .fetch_pages(&plan.page_bits, &mut arena)
            .map_err(|e| e.to_string())?;
        let views = KvViews { plan: &plan, fetch: &fetch, arena: &arena };
        let mut dk = Vec::new();
        let mut dv = Vec::new();
        materialize_read(&views, &kv, &meta, &mut dk, &mut dv);
        let row = meta.n_kv_heads * meta.d_head;
        for view in plan.active_views() {
            let codes = views.fetched(view.page);
            for l in 0..meta.layers {
                for t in view.t0..view.t1 {
                    let off = (l * meta.max_seq + t) * row;
                    let dt = t - view.t0;
                    for c in 0..row {
                        let (lazy_k, lazy_v) = match codes {
                            Some(cs) => (
                                BF16.decode(cs[span_k_base(l, dt, row) + c] as u32),
                                BF16.decode(cs[span_v_base(l, dt, row) + c] as u32),
                            ),
                            None => (
                                degrade_f32(kv.k[off + c], view.bits),
                                degrade_f32(kv.v[off + c], view.bits),
                            ),
                        };
                        if lazy_k.to_bits() != dk[off + c].to_bits()
                            || lazy_v.to_bits() != dv[off + c].to_bits()
                        {
                            return Err(format!(
                                "{codec} page {} bits {} (l={l} t={t} c={c}): lazy vs dense",
                                view.page, view.bits
                            ));
                        }
                    }
                }
            }
        }
        Ok(())
    });
}

#[test]
fn attention_digest_identical_between_view_and_dense_reads() {
    // The synthetic backend's attention readout digest — the end-to-end
    // quality observable — must be bit-identical whether it resolves the
    // lazy views or the materialized dense copy.
    let meta = tiny_meta();
    let lm = SynthLm::new(meta.clone(), 77);
    for (pos, clamp) in [(33usize, None), (64, Some(8)), (100, Some(4))] {
        let kv = kv_filled(&meta, pos, pos as u64);
        let engine = PolicyEngine::with_lanes(KvPolicy::Full, 1);
        let plan = engine.plan_pressured(&kv, &meta, clamp);
        let mut store = KvPageStore::new(&meta, Layout::Proposed, Codec::Zstd);
        store.sync(&kv, &meta);
        let mut arena = DecodeArena::new();
        let fetch = store.fetch_pages(&plan.page_bits, &mut arena).unwrap();
        // two identical cache states: decode mutates kv (it appends a row)
        let clone_kv = |src: &KvState| KvState {
            k: src.k.clone(),
            v: src.v.clone(),
            queries: src.queries.clone(),
            pos: src.pos,
        };
        let mut kv_view = clone_kv(&kv);
        let mut kv_dense = clone_kv(&kv);
        let views = KvViews { plan: &plan, fetch: &fetch, arena: &arena };
        let StepOutput { read_digest: dg_view, logits: lg_view } = lm
            .decode(&mut kv_view, KvRead::Views(views), 3, &plan.mask)
            .unwrap();
        let views = KvViews { plan: &plan, fetch: &fetch, arena: &arena };
        let mut dk = Vec::new();
        let mut dv = Vec::new();
        materialize_read(&views, &kv_dense, &meta, &mut dk, &mut dv);
        let StepOutput { read_digest: dg_dense, logits: lg_dense } = lm
            .decode(&mut kv_dense, KvRead::Dense { k: &dk, v: &dv }, 3, &plan.mask)
            .unwrap();
        assert_eq!(dg_view, dg_dense, "pos={pos} clamp={clamp:?}");
        assert_eq!(lg_view, lg_dense, "trajectory must not depend on the read path");
        // and the digest is value-sensitive: full-precision read differs
        // from a clamped one
        if clamp.is_some() {
            let free = engine.plan_pressured(&kv, &meta, None);
            let mut arena2 = DecodeArena::new();
            let mut store2 = KvPageStore::new(&meta, Layout::Proposed, Codec::Zstd);
            store2.sync(&kv, &meta);
            let fetch2 = store2.fetch_pages(&free.page_bits, &mut arena2).unwrap();
            let mut kv_free = clone_kv(&kv);
            let views2 = KvViews { plan: &free, fetch: &fetch2, arena: &arena2 };
            let out = lm
                .decode(&mut kv_free, KvRead::Views(views2), 3, &free.mask)
                .unwrap();
            assert_ne!(
                out.read_digest, dg_view,
                "pos={pos}: clamped read must be observable in the digest"
            );
        }
    }
}

fn dense_spec(n: usize, rate: f64, prompt: usize, output: usize) -> WorkloadSpec {
    WorkloadSpec {
        arrival: ArrivalProcess::Poisson { rate },
        tenants: vec![TenantSpec {
            name: "t".into(),
            weight: 1.0,
            policy: KvPolicy::Full,
            prompt: LengthDist::Fixed(prompt),
            output: LengthDist::Fixed(output),
        }],
        n_requests: n,
        vocab: 256,
        max_seq: 128,
        shared_prefixes: vec![],
    }
}

fn key(r: &TrafficResponse) -> (u64, Vec<u16>, u64, u64, u64, u64, u32) {
    (
        r.id,
        r.tokens.clone(),
        r.mean_nll.to_bits(),
        r.kv_fetched_bytes,
        r.kv_pages_digest,
        r.read_digest,
        r.evictions,
    )
}

#[test]
fn serve_view_path_matches_materialized_reference_end_to_end() {
    // The acceptance property: a contended serve (pressure clamps engaged,
    // evict/resume cycles forced) over the zero-materialization view path
    // yields bit-identical outcomes — schedule, tokens, fetched bytes,
    // stored-frame digests, AND attention-readout digests — to the
    // materializing reference, at {1, 2, 8, 32} lanes, both fetch modes,
    // and both codecs. Host-side copy volume is the only thing allowed to
    // differ, and it must be strictly smaller on the view path.
    // model seed + trace shape/seed + budget mirror the scheduler's
    // batched-vs-per-seq pressure test, which pins that this exact
    // configuration forces evictions AND engages the pressure clamp
    let lm = SynthLm::tiny(5);
    let trace = Trace::generate(&dense_spec(8, 8.0, 16, 48), 31);
    let budget = 9500u64;
    for codec in [Codec::Zstd, Codec::Lz4] {
        let cfg = SchedConfig {
            codec,
            collect_digests: true,
            ..SchedConfig::compressed(budget)
        };
        let run = |views: bool, lanes: usize, fetch: FetchMode| -> (SchedOutcome, ServeMetrics) {
            let la = Arc::new(LaneArray::new(lanes));
            let mut m = ServeMetrics::default();
            let cfg = SchedConfig { fetch, ..cfg.clone() };
            let out = if views {
                serve_trace(&lm, &trace, &cfg, la, &mut m).expect("serve")
            } else {
                serve_trace(&MaterializedRef(&lm), &trace, &cfg, la, &mut m).expect("serve")
            };
            (out, m)
        };
        let (base, bm) = run(false, 1, FetchMode::Batched);
        assert_eq!(base.responses.len(), 8, "{codec}: all requests complete");
        assert!(
            base.events.iter().any(|e| e.kind == EventKind::Evict),
            "{codec}: budget must force evict/resume or the test is vacuous"
        );
        assert!(
            base.pressure_steps[1] + base.pressure_steps[2] > 0,
            "{codec}: budget must engage the pressure clamp"
        );
        assert!(
            base.responses.iter().all(|r| r.read_digest != 0),
            "{codec}: every response must carry an attention-read witness"
        );
        for lanes in [1usize, 2, 8, 32] {
            for fetch in [FetchMode::Batched, FetchMode::PerSequence] {
                let (view, vm) = run(true, lanes, fetch);
                let tag = format!("{codec}/{lanes} lanes/{fetch:?}");
                assert_eq!(view.events, base.events, "{tag}: schedule diverged");
                assert_eq!(view.pressure_steps, base.pressure_steps, "{tag}");
                assert_eq!(
                    view.responses.iter().map(key).collect::<Vec<_>>(),
                    base.responses.iter().map(key).collect::<Vec<_>>(),
                    "{tag}: responses diverged"
                );
                assert_eq!(vm.fetched_bytes, bm.fetched_bytes, "{tag}");
                assert!(
                    vm.host_copy_bytes < bm.host_copy_bytes,
                    "{tag}: view path host copies {} must be < materialized {}",
                    vm.host_copy_bytes,
                    bm.host_copy_bytes
                );
            }
        }
    }
}

#[test]
fn pressure_is_observable_in_read_digests_without_perturbing_tokens() {
    // Same trace under a tight vs a slack budget: identical tokens (the
    // synthetic trajectory ignores reads) but different attention-readout
    // digests — degraded-read quality is now measurable end-to-end.
    // workload + budget mirror the scheduler's
    // pressure_degrades_reads_before_evicting test (known to engage the
    // clamp ladder under the tight budget and never under the slack one)
    let lm = SynthLm::tiny(5);
    let trace = Trace::generate(&dense_spec(10, 4.0, 24, 24), 19);
    let run = |budget: u64| -> SchedOutcome {
        let la = Arc::new(LaneArray::new(2));
        let mut m = ServeMetrics::default();
        let cfg = SchedConfig { collect_digests: true, ..SchedConfig::compressed(budget) };
        serve_trace(&lm, &trace, &cfg, la, &mut m).expect("serve")
    };
    let tight = run(4 * 3 * 2048);
    let slack = run(1 << 22);
    assert!(
        tight.pressure_steps[1] + tight.pressure_steps[2] > 0,
        "tight budget must clamp: {:?}",
        tight.pressure_steps
    );
    assert_eq!(tight.responses.len(), slack.responses.len());
    // completion order can legitimately differ between budgets; compare by id
    let by_id = |o: &SchedOutcome| -> std::collections::BTreeMap<u64, (Vec<u16>, u64)> {
        o.responses
            .iter()
            .map(|r| (r.id, (r.tokens.clone(), r.read_digest)))
            .collect()
    };
    let t_map = by_id(&tight);
    let s_map = by_id(&slack);
    assert_eq!(t_map.len(), s_map.len());
    let mut digests_differ = false;
    for (id, (tok_t, dg_t)) in &t_map {
        let (tok_s, dg_s) = &s_map[id];
        assert_eq!(tok_t, tok_s, "req {id}: trajectory must be pressure-invariant");
        if dg_t != dg_s {
            digests_differ = true;
        }
    }
    assert!(
        digests_differ,
        "clamped reads must be observable in at least one response's digest"
    );
}
