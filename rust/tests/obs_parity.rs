//! Observer-effect suite for the flight recorder (`obs`): a serve with
//! the recorder on must be bit-identical — schedule, responses, stored
//! page/readout digests, and every pre-existing metric — to the same
//! serve with the recorder off, across {1, 8, 32} lanes × both fetch
//! modes × prefetch on/off; a recorder-off serve returns no recording.
//! The drained event stream is itself deterministic:
//! `schedule_digest()` (prefetch advisories skipped) is identical
//! across the entire matrix, and the full `digest()` is identical
//! across lanes and fetch modes at a fixed prefetch setting — including
//! under injected faults, where the recovery-ladder rungs land in the
//! stream. The per-tenant attribution carried by `ServeMetrics`
//! conserves bit-exactly: the tenant entries sum to `attributed`, whose
//! counters equal the global fetch/host-copy totals.

use std::collections::BTreeSet;
use std::sync::Arc;

use camc::coordinator::{
    serve_trace, FetchMode, SchedConfig, SchedOutcome, ServeMetrics, TenantUsage, TrafficResponse,
};
use camc::engine::LaneArray;
use camc::memctrl::FaultPlan;
use camc::obs::{Event, EventKind, FlightRecording, RecorderCfg};
use camc::quant::policy::KvPolicy;
use camc::workload::arrival::ArrivalProcess;
use camc::workload::lengths::LengthDist;
use camc::workload::synthmodel::SynthLm;
use camc::workload::tenant::{TenantSpec, WorkloadSpec};
use camc::workload::trace::Trace;

fn dense_spec(n: usize, rate: f64, prompt: usize, output: usize) -> WorkloadSpec {
    WorkloadSpec {
        arrival: ArrivalProcess::Poisson { rate },
        tenants: vec![TenantSpec {
            name: "t".into(),
            weight: 1.0,
            policy: KvPolicy::Full,
            prompt: LengthDist::Fixed(prompt),
            output: LengthDist::Fixed(output),
        }],
        n_requests: n,
        vocab: 256,
        max_seq: 128,
        shared_prefixes: vec![],
    }
}

fn two_tenant_spec(n: usize) -> WorkloadSpec {
    WorkloadSpec {
        arrival: ArrivalProcess::Poisson { rate: 8.0 },
        tenants: vec![
            TenantSpec {
                name: "chat".into(),
                weight: 0.6,
                policy: KvPolicy::Full,
                prompt: LengthDist::Fixed(16),
                output: LengthDist::Fixed(48),
            },
            TenantSpec {
                name: "batch".into(),
                weight: 0.4,
                policy: KvPolicy::Full,
                prompt: LengthDist::Fixed(16),
                output: LengthDist::Fixed(32),
            },
        ],
        n_requests: n,
        vocab: 256,
        max_seq: 128,
        shared_prefixes: vec![],
    }
}

/// Everything deterministic about a response (wall time excluded).
fn key(r: &TrafficResponse) -> (u64, Vec<u16>, u64, u64, u64, u64, u32, u64) {
    (
        r.id,
        r.tokens.clone(),
        r.mean_nll.to_bits(),
        r.kv_fetched_bytes,
        r.kv_pages_digest,
        r.read_digest,
        r.evictions,
        r.recovered_faults,
    )
}

fn serve(
    lm: &SynthLm,
    trace: &Trace,
    cfg: &SchedConfig,
    lanes: usize,
) -> (SchedOutcome, ServeMetrics) {
    let la = Arc::new(LaneArray::new(lanes));
    let mut m = ServeMetrics::default();
    let cfg = SchedConfig { collect_digests: true, ..cfg.clone() };
    let out = serve_trace(lm, trace, &cfg, la, &mut m).expect("serve_trace");
    (out, m)
}

/// Recorder-on vs recorder-off: schedule, responses, and every metric
/// (the per-tenant attribution included) must match bit-exactly.
fn assert_observer_free(
    tag: &str,
    off: &(SchedOutcome, ServeMetrics),
    on: &(SchedOutcome, ServeMetrics),
) {
    let ((base, bm), (o, m)) = (off, on);
    assert!(base.flight.is_none(), "{tag}: recorder-off run recorded");
    assert!(o.flight.is_some(), "{tag}: recorder-on run lost its recording");
    assert_eq!(o.events, base.events, "{tag}: schedule diverged");
    assert_eq!(o.peak_active, base.peak_active, "{tag}");
    assert_eq!(o.steps, base.steps, "{tag}");
    assert_eq!(o.pressure_steps, base.pressure_steps, "{tag}");
    assert_eq!(
        o.responses.iter().map(key).collect::<Vec<_>>(),
        base.responses.iter().map(key).collect::<Vec<_>>(),
        "{tag}: responses diverged"
    );
    assert_eq!(m.steps, bm.steps, "{tag}");
    assert_eq!(m.fetched_bytes, bm.fetched_bytes, "{tag}: fetched bytes");
    assert_eq!(m.fetch_frames, bm.fetch_frames, "{tag}: fetched frames");
    assert_eq!(m.fetch_dispatches, bm.fetch_dispatches, "{tag}: dispatches");
    assert_eq!(m.host_copy_bytes, bm.host_copy_bytes, "{tag}: host copies");
    assert_eq!(m.faults_injected, bm.faults_injected, "{tag}: faults");
    assert_eq!(m.retries, bm.retries, "{tag}: retries");
    assert_eq!(m.parity_repairs, bm.parity_repairs, "{tag}: repairs");
    assert_eq!(m.salvaged_reads, bm.salvaged_reads, "{tag}: salvages");
    assert_eq!(m.quarantined_seqs, bm.quarantined_seqs, "{tag}: quarantines");
    assert_eq!(m.prefetch_issued, bm.prefetch_issued, "{tag}: prefetch");
    assert_eq!(m.prefetch_hits, bm.prefetch_hits, "{tag}: prefetch hits");
    assert_eq!(m.prefetch_misses, bm.prefetch_misses, "{tag}: misses");
    assert_eq!(
        m.prefetch_wasted_bytes, bm.prefetch_wasted_bytes,
        "{tag}: waste"
    );
    assert_eq!(m.sync_fetch_ns.to_bits(), bm.sync_fetch_ns.to_bits(), "{tag}");
    assert_eq!(
        m.overlapped_fetch_ns.to_bits(),
        bm.overlapped_fetch_ns.to_bits(),
        "{tag}"
    );
    assert_eq!(m.tenants, bm.tenants, "{tag}: per-tenant stats");
    assert_eq!(m.tenant_usage, bm.tenant_usage, "{tag}: attribution");
    assert_eq!(m.attributed, bm.attributed, "{tag}: attribution totals");
}

/// The conservation law: tenant entries sum bit-exactly to `attributed`,
/// whose byte/frame counters equal the pre-existing globals.
fn assert_conserved(tag: &str, m: &ServeMetrics) {
    assert_eq!(
        m.attributed.dram_bytes, m.fetched_bytes,
        "{tag}: dram bytes not conserved"
    );
    assert_eq!(
        m.attributed.lane_frames, m.fetch_frames,
        "{tag}: lane frames not conserved"
    );
    assert_eq!(
        m.attributed.host_copy_bytes, m.host_copy_bytes,
        "{tag}: host-copy bytes not conserved"
    );
    let mut sum = TenantUsage::default();
    for u in m.tenant_usage.values() {
        sum.add(u);
    }
    assert_eq!(sum, m.attributed, "{tag}: tenant sum != attributed");
}

fn flight(run: &(SchedOutcome, ServeMetrics)) -> &FlightRecording {
    run.0.flight.as_ref().expect("recorder-on run records")
}

#[test]
fn recorder_is_observer_free_and_stream_digests_are_deterministic() {
    // The acceptance matrix: a budget tight enough to clamp AND force
    // evict/resume cycles, served at {1, 8, 32} lanes × both fetch
    // modes × prefetch on/off — recorder-on bit-identical to
    // recorder-off everywhere, one schedule digest across the whole
    // matrix, one full digest per prefetch setting.
    let lm = SynthLm::tiny(5);
    let trace = Trace::generate(&dense_spec(8, 8.0, 16, 48), 31);
    let budget = 9500u64;
    let mut schedule_digests = BTreeSet::new();
    let mut full_digests = [BTreeSet::new(), BTreeSet::new()];
    for prefetch in [false, true] {
        for fetch in [FetchMode::Batched, FetchMode::PerSequence] {
            for lanes in [1usize, 8, 32] {
                let cfg = SchedConfig {
                    fetch,
                    prefetch,
                    ..SchedConfig::compressed(budget)
                };
                let tag = format!("{fetch:?}/{lanes} lanes/prefetch={prefetch}");
                let off = serve(&lm, &trace, &cfg, lanes);
                let on = serve(
                    &lm,
                    &trace,
                    &SchedConfig {
                        record: Some(RecorderCfg::default()),
                        ..cfg
                    },
                    lanes,
                );
                assert_observer_free(&tag, &off, &on);
                assert_conserved(&tag, &on.1);
                assert_conserved(&tag, &off.1);
                let f = flight(&on);
                assert!(!f.events.is_empty(), "{tag}: empty recording");
                assert_eq!(f.dropped(), 0, "{tag}: unexpectedly overflowed");
                schedule_digests.insert(f.schedule_digest());
                full_digests[usize::from(prefetch)].insert(f.digest());
            }
        }
    }
    assert_eq!(
        schedule_digests.len(),
        1,
        "schedule digest must be identical across the entire matrix: {schedule_digests:?}"
    );
    for (i, d) in full_digests.iter().enumerate() {
        assert_eq!(
            d.len(),
            1,
            "full digest must be identical across lanes/fetch modes at prefetch={}: {d:?}",
            i == 1
        );
    }
    // prefetch on records advisory events (speculation is proven to arm
    // on this workload), so the full digests differ across the two
    // settings — else the advisory split is vacuous
    assert_ne!(full_digests[0], full_digests[1]);
}

#[test]
fn recording_covers_lifecycle_fetch_and_pressure() {
    let lm = SynthLm::tiny(5);
    let trace = Trace::generate(&dense_spec(8, 8.0, 16, 48), 31);
    let cfg = SchedConfig {
        record: Some(RecorderCfg::default()),
        ..SchedConfig::compressed(9500)
    };
    let (out, m) = serve(&lm, &trace, &cfg, 8);
    let f = out.flight.as_ref().expect("recording");
    // virtual time is monotone and never wall clock
    assert!(f.events.windows(2).all(|w| w[0].t_ps <= w[1].t_ps));
    // every request admits and finishes in the stream
    let admitted: BTreeSet<u64> = f
        .events
        .iter()
        .filter(|e| e.kind == EventKind::Admit)
        .map(|e| e.seq)
        .collect();
    let finished: BTreeSet<u64> = f
        .events
        .iter()
        .filter(|e| e.kind == EventKind::Finish)
        .map(|e| e.seq)
        .collect();
    let ids: BTreeSet<u64> = out.responses.iter().map(|r| r.id).collect();
    assert_eq!(admitted, ids);
    assert_eq!(finished, ids);
    // the tight budget exercises eviction, resume, and the pressure rung
    for kind in [EventKind::Evict, EventKind::Resume] {
        assert!(
            f.events.iter().any(|e| e.kind == kind),
            "missing {kind:?} in the stream"
        );
    }
    assert!(f
        .events
        .iter()
        .any(|e| matches!(e.kind, EventKind::Pressure { level } if level > 0)));
    // the fetch timeline pairs DRAM service with lane decode each step
    let dram = f
        .events
        .iter()
        .filter(|e| matches!(e.kind, EventKind::FetchDram { .. }))
        .count();
    let lanes_ev = f
        .events
        .iter()
        .filter(|e| matches!(e.kind, EventKind::FetchLanes { .. }))
        .count();
    assert!(dram > 0);
    assert_eq!(dram, lanes_ev);
    assert!(f
        .events
        .iter()
        .any(|e| matches!(e.kind, EventKind::HostCopy { .. })));
    // the recorded DRAM intervals sum to exactly the run's fetch traffic
    // (swap-in reads are response-side accounting, not fetch events)
    let recorded: u64 = f
        .events
        .iter()
        .map(|e| match e.kind {
            EventKind::FetchDram { bytes, .. } => bytes,
            _ => 0,
        })
        .sum();
    assert_eq!(recorded, m.fetched_bytes);
    // round-trips through the CAMCEVT1 binary form
    let back = FlightRecording::from_bytes(&f.to_bytes()).expect("round-trip");
    assert_eq!(&back, f);
    assert_eq!(back.digest(), f.digest());
}

#[test]
fn recovery_rungs_land_in_the_stream_and_digest_identically() {
    // Injected faults climb the recovery ladder; the per-sequence rung
    // deltas must appear as Recovery records whose totals equal the run
    // metrics — and the stream digest stays identical across lanes and
    // fetch modes (fault draws are virtual-site state, not timing).
    let lm = SynthLm::tiny(9);
    let trace = Trace::generate(&dense_spec(16, 2.0, 16, 32), 23);
    let plan = Arc::new(FaultPlan {
        seed: 77,
        p_plane_flip: 220,
        p_header_flip: 17,
        p_transient: 80,
        p_lane_fault: 40,
        flip_plane: None,
    });
    let mut digests = BTreeSet::new();
    for fetch in [FetchMode::Batched, FetchMode::PerSequence] {
        for lanes in [1usize, 8, 32] {
            let cfg = SchedConfig {
                fetch,
                parity: true,
                faults: Some(Arc::clone(&plan)),
                record: Some(RecorderCfg::default()),
                ..SchedConfig::compressed(1 << 20)
            };
            let tag = format!("{fetch:?}/{lanes} lanes");
            let (out, m) = serve(&lm, &trace, &cfg, lanes);
            assert!(m.faults_injected > 0, "{tag}: fault plan never fired");
            assert!(m.retries > 0, "{tag}: no transient retries");
            assert!(m.parity_repairs > 0, "{tag}: parity on must repair");
            let f = out.flight.as_ref().expect("recording");
            let (mut faults, mut retries, mut repairs, mut salvaged) = (0u64, 0u64, 0u64, 0u64);
            for e in &f.events {
                if let EventKind::Recovery {
                    faults: fa,
                    retries: re,
                    parity_repairs: pr,
                    salvaged: sa,
                } = e.kind
                {
                    faults += u64::from(fa);
                    retries += u64::from(re);
                    repairs += u64::from(pr);
                    salvaged += u64::from(sa);
                }
            }
            assert_eq!(faults, m.faults_injected, "{tag}: fault rungs");
            assert_eq!(retries, m.retries, "{tag}: retry rungs");
            assert_eq!(repairs, m.parity_repairs, "{tag}: repair rungs");
            assert_eq!(salvaged, m.salvaged_reads, "{tag}: salvage rungs");
            digests.insert(f.digest());
        }
    }
    assert_eq!(
        digests.len(),
        1,
        "fault-run stream digest must be identical across lanes/fetch modes: {digests:?}"
    );
}

#[test]
fn shard_advisories_stay_out_of_solo_streams_and_off_the_schedule_digest() {
    // Shard placement records (ShardSteer/ShardSteal) are emitted only
    // when shards > 1: a solo run's event stream — and therefore its
    // full digest — is byte-identical to the pre-sharding recorder
    // format. A sharded run may add ONLY those advisory records: the
    // schedule digest (advisories skipped) never moves, and the new
    // binary tags round-trip through the CAMCEVT1 form.
    let lm = SynthLm::tiny(5);
    let trace = Trace::generate(&dense_spec(8, 8.0, 16, 48), 31);
    let cfg = SchedConfig {
        record: Some(RecorderCfg::default()),
        ..SchedConfig::compressed(9500)
    };
    let solo = serve(&lm, &trace, &cfg, 8);
    let f_solo = flight(&solo);
    let is_shard_advisory = |k: &EventKind| {
        matches!(k, EventKind::ShardSteer { .. } | EventKind::ShardSteal { .. })
    };
    assert!(
        !f_solo.events.iter().any(|e| is_shard_advisory(&e.kind)),
        "solo run emitted a shard placement record"
    );

    let sharded = serve(&lm, &trace, &SchedConfig { shards: 4, ..cfg.clone() }, 8);
    let f_sh = flight(&sharded);
    assert_eq!(
        f_sh.schedule_digest(),
        f_solo.schedule_digest(),
        "shard advisories moved the schedule digest"
    );
    for e in &f_sh.events {
        if is_shard_advisory(&e.kind) {
            assert!(e.kind.is_advisory(), "shard records must classify advisory");
        }
    }
    // stripped of the advisories, the sharded stream IS the solo stream
    let stripped = FlightRecording {
        events: f_sh
            .events
            .iter()
            .filter(|e| !is_shard_advisory(&e.kind))
            .copied()
            .collect(),
    };
    assert_eq!(&stripped, f_solo, "sharded stream diverged beyond advisories");

    // the new binary tags round-trip (synthetic stream, so the encode /
    // decode arms are pinned even if this workload never steers)
    let mut events = f_solo.events.clone();
    events.push(Event {
        step: 1,
        t_ps: 123,
        seq: 7,
        kind: EventKind::ShardSteer { from: 3, to: 0 },
    });
    events.push(Event {
        step: 2,
        t_ps: 456,
        seq: 9,
        kind: EventKind::ShardSteal { from: 1, to: 2 },
    });
    let synth = FlightRecording { events };
    let back = FlightRecording::from_bytes(&synth.to_bytes()).expect("round-trip");
    assert_eq!(back, synth);
    assert_eq!(
        synth.schedule_digest(),
        f_solo.schedule_digest(),
        "appended advisories must not move the schedule digest"
    );
    assert_ne!(synth.digest(), f_solo.digest(), "full digest must see them");
}

#[test]
fn tenant_attribution_splits_bandwidth_and_energy() {
    // Two tenants with different output lengths: every tenant the trace
    // actually serves must be attributed, the split must be non-trivial,
    // and the public accessors must agree with the raw entries.
    let lm = SynthLm::tiny(5);
    let trace = Trace::generate(&two_tenant_spec(16), 31);
    let (out, m) = serve(&lm, &trace, &SchedConfig::compressed(1 << 20), 8);
    assert_conserved("two-tenant", &m);
    let served: BTreeSet<u32> = out.responses.iter().map(|r| r.tenant).collect();
    assert_eq!(served.len(), 2, "seed must mix both tenants");
    assert_eq!(m.tenant_usage.keys().copied().collect::<BTreeSet<_>>(), served);
    for (&t, u) in &m.tenant_usage {
        assert!(u.dram_bytes > 0, "tenant {t} moved no DRAM bytes");
        assert!(u.host_copy_bytes > 0, "tenant {t} copied no host bytes");
        assert!(u.dram_ps > 0 && u.lane_ps > 0 && u.energy_fj > 0);
        assert_eq!(m.tenant_bandwidth_bytes(t), u.dram_bytes);
        assert_eq!(m.tenant_energy_pj(t).to_bits(), u.energy_pj().to_bits());
        // the modeled components are consistent derivations of the bytes
        assert_eq!(u.dram_ns(), u.dram_ps as f64 / 1000.0);
        assert_eq!(u.lane_ns(), u.lane_ps as f64 / 1000.0);
    }
    // unknown tenants read as zero, not a panic
    assert_eq!(m.tenant_bandwidth_bytes(99), 0);
    assert_eq!(m.tenant_energy_pj(99), 0.0);
}
