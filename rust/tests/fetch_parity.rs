//! Differential / fuzz-style property tests for the batched decode fetch
//! path (seeded via `util::check` — no fuzzing dependency): random block
//! contents, sizes, and bit-plane prefixes round-trip through BOTH codecs
//! (lz4 and zstdlike) at 1 vs N lanes, batched (`fetch_group` /
//! `fetch_sequences`) vs per-sequence (`load` / `fetch_pages`), asserting
//! byte identity everywhere — including pressure-clamped plane prefixes.
//! Batching must change *where* a frame decodes, never what it produces.

use std::sync::Arc;

use camc::compress::Codec;
use camc::coordinator::{fetch_sequences, DecodeArena, FetchOutcome, KvPageStore};
use camc::engine::LaneArray;
use camc::fmt::minifloat::BF16;
use camc::fmt::{truncate_to_planes, Dtype};
use camc::memctrl::{Layout, MemController};
use camc::quant::policy::apply_pressure;
use camc::runtime::model::{KvState, ModelMeta};
use camc::util::check::check;
use camc::util::rng::Xoshiro256;

fn weight_codes(n: usize, seed: u64) -> Vec<u16> {
    let mut r = Xoshiro256::new(seed);
    (0..n)
        .map(|_| BF16.encode((r.normal() * 0.02) as f32) as u16)
        .collect()
}

#[test]
fn fetch_group_differential_vs_per_region_loads() {
    // Random region mixes (weights + KV), random prefixes, both codecs,
    // serial vs parallel lanes: the grouped single-dispatch fetch must be
    // byte-identical to per-region loads, and weight reads must equal
    // plane-truncation of the source codes.
    check("fetch_group_differential", 14, |g| {
        let codec = if g.rng.next_f64() < 0.5 {
            Codec::Lz4
        } else {
            Codec::Zstd
        };
        let nw = g.usize_in(1, 8000);
        let w = weight_codes(nw, g.case_seed);
        let wt = camc::fmt::CodeTensor::new(Dtype::Bf16, w.clone(), vec![nw]);
        let tokens = g.usize_in(1, 48);
        let channels = g.usize_in(1, 64);
        let kv: Vec<u16> = (0..tokens * channels)
            .map(|_| g.rng.next_u64() as u16)
            .collect();
        let keep_w = g.usize_in(0, 16) as u32;
        let keep_k = g.usize_in(0, 16) as u32;
        let mut serial_outs: Option<Vec<Vec<u16>>> = None;
        for lanes in [1usize, 2, 8] {
            let mut grouped = MemController::with_lanes(Layout::Proposed, codec, lanes);
            let gw = grouped.store_weights("w", &wt);
            let gk = grouped.store_kv("kv", Dtype::Bf16, tokens, channels, &kv);
            let mut reference = MemController::with_lanes(Layout::Proposed, codec, lanes);
            let rw = reference.store_weights("w", &wt);
            let rk = reference.store_kv("kv", Dtype::Bf16, tokens, channels, &kv);
            let (outs, gs) = grouped
                .fetch_group(&[(gw, keep_w), (gk, keep_k)], None)
                .map_err(|e| e.to_string())?;
            let (lw, sw) = reference.load(rw, keep_w, None).map_err(|e| e.to_string())?;
            let (lk, sk) = reference.load(rk, keep_k, None).map_err(|e| e.to_string())?;
            if outs[0] != lw || outs[1] != lk {
                return Err(format!("{codec} {lanes} lanes: grouped codes diverged"));
            }
            // ground truth for the weights region: exact plane truncation
            for (i, (&src, &got)) in w.iter().zip(&outs[0]).enumerate() {
                let want = truncate_to_planes(src, Dtype::Bf16, keep_w);
                if got != want {
                    return Err(format!("{codec} {lanes} lanes: w[{i}] keep={keep_w}"));
                }
            }
            if gs.dram_bytes != sw.dram_bytes + sk.dram_bytes
                || gs.frames != sw.frames + sk.frames
                || gs.dispatches != 1
            {
                return Err(format!("{codec} {lanes} lanes: accounting diverged"));
            }
            // and identical across lane counts (vs the 1-lane result)
            match serial_outs.take() {
                None => serial_outs = Some(outs),
                Some(s) => {
                    if s != outs {
                        return Err(format!("{codec} {lanes} lanes vs serial diverged"));
                    }
                    serial_outs = Some(s);
                }
            }
        }
        Ok(())
    });
}

fn tiny_meta() -> ModelMeta {
    ModelMeta {
        vocab: 256,
        layers: 2,
        d_model: 32,
        n_heads: 4,
        n_kv_heads: 2,
        d_head: 8,
        max_seq: 128,
        kv_channels: 16,
        prefill_len: 32,
        page_tokens: 16,
        n_pages: 8,
        param_names: vec![],
    }
}

fn kv_filled(meta: &ModelMeta, pos: usize, seed: u64) -> KvState {
    let row = meta.n_kv_heads * meta.d_head;
    let mut kv = KvState {
        k: vec![0.0; meta.layers * meta.max_seq * row],
        v: vec![0.0; meta.layers * meta.max_seq * row],
        queries: vec![0.0; meta.layers * meta.n_heads * meta.d_head],
        pos,
    };
    let mut r = Xoshiro256::new(seed);
    let scales: Vec<f32> = (0..row).map(|_| 2f32.powf(r.normal() as f32)).collect();
    for l in 0..meta.layers {
        for t in 0..pos {
            for c in 0..row {
                kv.k[(l * meta.max_seq + t) * row + c] =
                    scales[c] * (1.0 + 0.05 * r.normal() as f32);
                kv.v[(l * meta.max_seq + t) * row + c] =
                    scales[c] * (1.0 + 0.05 * r.normal() as f32);
            }
        }
    }
    kv
}

fn outcomes_match(
    g: &FetchOutcome,
    ga: &DecodeArena,
    w: &FetchOutcome,
    wa: &DecodeArena,
) -> Result<(), String> {
    let gp: Vec<(usize, &[u16])> = g.decoded(ga).collect();
    let wp: Vec<(usize, &[u16])> = w.decoded(wa).collect();
    if gp != wp {
        return Err("page codes diverged".into());
    }
    if g.stats.frames != w.stats.frames
        || g.stats.dram_bytes != w.stats.dram_bytes
        || g.stats.logical_bytes != w.stats.logical_bytes
        || g.raw_tail_bytes != w.raw_tail_bytes
    {
        return Err("accounting diverged".into());
    }
    if (g.stats.engine_ns - w.stats.engine_ns).abs() > 1e-6 {
        return Err("engine_ns diverged".into());
    }
    Ok(())
}

#[test]
fn fetch_sequences_differential_vs_fetch_pages() {
    // Random sequence populations (sizes, codecs), random per-page plane
    // prefixes — including the scheduler's pressure ladder applied on top
    // (8- and 4-plane clamps) and skipped pages — batched cross-sequence
    // fetch vs the per-sequence reference, at 1/2/8 lanes: byte-identical
    // pages, identical physical accounting.
    check("fetch_sequences_differential", 10, |g| {
        let meta = tiny_meta();
        let codec = if g.rng.next_f64() < 0.5 {
            Codec::Lz4
        } else {
            Codec::Zstd
        };
        let nseq = g.usize_in(1, 5);
        let positions: Vec<usize> = (0..nseq).map(|_| g.usize_in(1, 120)).collect();
        let kvs: Vec<KvState> = positions
            .iter()
            .enumerate()
            .map(|(i, &pos)| kv_filled(&meta, pos, g.case_seed ^ i as u64))
            .collect();
        // per-page plan: random bits in {0, 4, 8, 9, 16}, sometimes with
        // the scheduler's pressure clamp applied on top
        let bits: Vec<Vec<u32>> = kvs
            .iter()
            .map(|kv| {
                let npages = kv.pos.div_ceil(16).max(1);
                let mut b: Vec<u32> = (0..npages)
                    .map(|_| [0u32, 4, 8, 9, 16][g.rng.index(5)])
                    .collect();
                if g.rng.next_f64() < 0.5 {
                    let clamp = if g.rng.next_f64() < 0.5 { 8 } else { 4 };
                    apply_pressure(&mut b, clamp);
                }
                b
            })
            .collect();
        // reference: per-sequence decode
        let mut ref_stores: Vec<KvPageStore> = kvs
            .iter()
            .map(|kv| {
                let mut s = KvPageStore::new(&meta, Layout::Proposed, codec);
                s.sync(kv, &meta);
                s
            })
            .collect();
        let mut ref_arena = DecodeArena::new();
        let want: Vec<FetchOutcome> = ref_stores
            .iter_mut()
            .zip(&bits)
            .map(|(s, b)| s.fetch_pages(b, &mut ref_arena).map_err(|e| e.to_string()))
            .collect::<Result<_, _>>()?;
        for lanes in [1usize, 2, 8] {
            let la = Arc::new(LaneArray::new(lanes));
            let mut stores: Vec<KvPageStore> = kvs
                .iter()
                .map(|kv| {
                    let mut s =
                        KvPageStore::with_shared(&meta, Layout::Proposed, codec, Arc::clone(&la));
                    s.sync(kv, &meta);
                    s
                })
                .collect();
            let mut arena = DecodeArena::new();
            let mut seqs: Vec<(&mut KvPageStore, &[u32])> = stores
                .iter_mut()
                .zip(bits.iter())
                .map(|(s, b)| (s, b.as_slice()))
                .collect();
            let got = fetch_sequences(&mut seqs, &la, &mut arena).map_err(|e| e.to_string())?;
            drop(seqs);
            for (si, (gi, wi)) in got.iter().zip(&want).enumerate() {
                outcomes_match(gi, &arena, wi, &ref_arena)
                    .map_err(|e| format!("{codec} {lanes} lanes seq {si}: {e}"))?;
            }
        }
        Ok(())
    });
}

#[test]
fn fetch_sequences_is_idempotent_and_stateless() {
    // Fetching is a read: repeating the same batched fetch returns the
    // same bytes and leaves stored frames untouched (digest-pinned).
    let meta = tiny_meta();
    let kv = kv_filled(&meta, 100, 7);
    let lanes = Arc::new(LaneArray::new(4));
    let mut store =
        KvPageStore::with_shared(&meta, Layout::Proposed, Codec::Zstd, Arc::clone(&lanes));
    store.sync(&kv, &meta);
    let digest = store.frames_digest();
    let bits = vec![8u32; 7];
    let mut arena_a = DecodeArena::new();
    let first = {
        let mut seqs: Vec<(&mut KvPageStore, &[u32])> = vec![(&mut store, bits.as_slice())];
        fetch_sequences(&mut seqs, &lanes, &mut arena_a).unwrap()
    };
    let mut arena_b = DecodeArena::new();
    let second = {
        let mut seqs: Vec<(&mut KvPageStore, &[u32])> = vec![(&mut store, bits.as_slice())];
        fetch_sequences(&mut seqs, &lanes, &mut arena_b).unwrap()
    };
    let pages_a: Vec<(usize, Vec<u16>)> =
        first[0].decoded(&arena_a).map(|(p, c)| (p, c.to_vec())).collect();
    let pages_b: Vec<(usize, Vec<u16>)> =
        second[0].decoded(&arena_b).map(|(p, c)| (p, c.to_vec())).collect();
    assert_eq!(pages_a, pages_b);
    assert_eq!(first[0].dram_bytes_total(), second[0].dram_bytes_total());
    assert_eq!(store.frames_digest(), digest, "reads must not mutate frames");
}
