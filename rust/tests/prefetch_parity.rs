//! Differential suite for the predictive prefetch engine: a serve with
//! speculation on (double-buffered arenas, next-step fetch issued while
//! the current step computes) must be bit-identical to the synchronous
//! reference — schedule, tokens, fetched bytes, stored-frame digests,
//! attention-readout digests, and every fetch-domain metric — across
//! {1, 8, 32} lanes × both fetch modes × both codecs, under pressure
//! clamps and forced evict/resume cycles, and under adversarially
//! chaos-perturbed predictions. Only the `prefetch_*` counters and the
//! modeled overlapped-latency figures may differ from the synchronous
//! run (the f64 latency sums additionally tolerate last-bit drift from
//! hit/fallback merge order).

use std::sync::Arc;

use camc::compress::Codec;
use camc::coordinator::{
    serve_trace, EventKind, FetchMode, SchedConfig, SchedOutcome, ServeMetrics, TrafficResponse,
};
use camc::engine::LaneArray;
use camc::quant::policy::KvPolicy;
use camc::util::check::check;
use camc::workload::arrival::ArrivalProcess;
use camc::workload::lengths::LengthDist;
use camc::workload::synthmodel::SynthLm;
use camc::workload::tenant::{TenantSpec, WorkloadSpec};
use camc::workload::trace::Trace;

fn dense_spec(n: usize, rate: f64, prompt: usize, output: usize) -> WorkloadSpec {
    WorkloadSpec {
        arrival: ArrivalProcess::Poisson { rate },
        tenants: vec![TenantSpec {
            name: "t".into(),
            weight: 1.0,
            policy: KvPolicy::Full,
            prompt: LengthDist::Fixed(prompt),
            output: LengthDist::Fixed(output),
        }],
        n_requests: n,
        vocab: 256,
        max_seq: 128,
        shared_prefixes: vec![],
    }
}

/// Everything deterministic about a response (wall time excluded).
fn key(r: &TrafficResponse) -> (u64, Vec<u16>, u64, u64, u64, u64, u32, u64) {
    (
        r.id,
        r.tokens.clone(),
        r.mean_nll.to_bits(),
        r.kv_fetched_bytes,
        r.kv_pages_digest,
        r.read_digest,
        r.evictions,
        r.recovered_faults,
    )
}

fn serve(
    lm: &SynthLm,
    trace: &Trace,
    cfg: &SchedConfig,
    lanes: usize,
) -> (SchedOutcome, ServeMetrics) {
    let la = Arc::new(LaneArray::new(lanes));
    let mut m = ServeMetrics::default();
    let cfg = SchedConfig { collect_digests: true, ..cfg.clone() };
    let out = serve_trace(lm, trace, &cfg, la, &mut m).expect("serve_trace");
    (out, m)
}

/// The integer-domain halves of both runs must match exactly; the f64
/// latency sums are permitted last-bit drift only (the prefetch consume
/// path merges hit stats before fallback stats, the synchronous path
/// merges in page order — same addends, different f64 sum order).
fn assert_serve_identical(
    tag: &str,
    sync: &(SchedOutcome, ServeMetrics),
    pf: &(SchedOutcome, ServeMetrics),
) {
    let ((base, bm), (o, m)) = (sync, pf);
    assert_eq!(o.events, base.events, "{tag}: schedule diverged");
    assert_eq!(o.peak_active, base.peak_active, "{tag}");
    assert_eq!(o.steps, base.steps, "{tag}");
    assert_eq!(o.pressure_steps, base.pressure_steps, "{tag}");
    assert_eq!(
        o.responses.iter().map(key).collect::<Vec<_>>(),
        base.responses.iter().map(key).collect::<Vec<_>>(),
        "{tag}: responses diverged"
    );
    assert_eq!(m.steps, bm.steps, "{tag}");
    assert_eq!(m.fetched_bytes, bm.fetched_bytes, "{tag}: fetched bytes");
    assert_eq!(m.fetch_frames, bm.fetch_frames, "{tag}: fetched frames");
    assert_eq!(m.fetch_dispatches, bm.fetch_dispatches, "{tag}: dispatches");
    assert_eq!(m.host_copy_bytes, bm.host_copy_bytes, "{tag}: host copies");
    assert_eq!(m.tenants, bm.tenants, "{tag}: per-tenant stats");
    assert_eq!(m.fetch_latency_steps, bm.fetch_latency_steps, "{tag}");
    assert_eq!(m.steps_8plus, bm.steps_8plus, "{tag}");
    let rel = (m.sync_fetch_ns - bm.sync_fetch_ns).abs() / bm.sync_fetch_ns.max(1.0);
    assert!(
        rel < 1e-9,
        "{tag}: modeled sync latency drifted beyond merge-order noise: {} vs {}",
        m.sync_fetch_ns,
        bm.sync_fetch_ns
    );
}

#[test]
fn prefetch_serve_is_bit_identical_under_pressure_and_eviction() {
    // The acceptance property: with a budget tight enough to engage the
    // pressure clamp AND force evict/resume cycles, the speculative
    // serve is bit-identical to the synchronous one at every lane
    // count, in both fetch modes, with both codecs — and a clean
    // completed run consumes every speculated page (wasted == 0).
    // trace shape/seed + budget mirror the scheduler's batched-vs-
    // per-seq pressure test, pinned there to evict AND clamp
    let lm = SynthLm::tiny(5);
    let trace = Trace::generate(&dense_spec(8, 8.0, 16, 48), 31);
    let budget = 9500u64;
    for codec in [Codec::Zstd, Codec::Lz4] {
        for fetch in [FetchMode::Batched, FetchMode::PerSequence] {
            let cfg = SchedConfig { codec, fetch, ..SchedConfig::compressed(budget) };
            let sync = serve(&lm, &trace, &cfg, 1);
            assert_eq!(sync.0.responses.len(), 8, "{codec}: all requests complete");
            assert!(
                sync.0.events.iter().any(|e| e.kind == EventKind::Evict),
                "{codec}/{fetch:?}: budget must force evictions or the test is vacuous"
            );
            assert!(
                sync.0.pressure_steps[1] + sync.0.pressure_steps[2] > 0,
                "{codec}/{fetch:?}: budget must engage the pressure clamp"
            );
            for lanes in [1usize, 8, 32] {
                let pcfg = SchedConfig { prefetch: true, ..cfg.clone() };
                let pf = serve(&lm, &trace, &pcfg, lanes);
                let tag = format!("{codec}/{fetch:?}/{lanes} lanes");
                assert_serve_identical(&tag, &sync, &pf);
                let m = &pf.1;
                assert!(m.prefetch_issued > 0, "{tag}: speculation never armed");
                assert_eq!(
                    m.prefetch_wasted_bytes, 0,
                    "{tag}: clean completed run discarded speculated bytes"
                );
                assert_eq!(
                    m.prefetch_hits, m.prefetch_issued,
                    "{tag}: clean completed run must consume every speculated page"
                );
                // evict/resume + admissions are never speculated: their
                // first post-(re)admission fetch is a legitimate miss
                assert!(m.prefetch_misses > 0, "{tag}: evict/resume must miss");
                assert!(
                    m.prefetch_hit_rate() > 0.5,
                    "{tag}: prediction should dominate: {}",
                    m.prefetch_hit_rate()
                );
                // hits leave the step's blocking fetch smaller than the
                // synchronous model of the same reads
                assert!(
                    m.overlapped_fetch_ns < m.sync_fetch_ns,
                    "{tag}: overlap must shrink modeled step-blocking latency"
                );
            }
        }
    }
}

#[test]
fn forced_mispredicts_recover_bit_identically_property() {
    // Adversarial invalidation: the chaos knob perturbs the predicted
    // pressure clamp every `chaos` steps, guaranteeing speculated pages
    // whose kept-bits mismatch the real plan. Those regions must be
    // invalidated (counted as misses, bytes as wasted) and re-fetched
    // synchronously — with the serve still bit-identical to the
    // no-prefetch reference at every sampled configuration.
    check("forced_mispredict_parity", 10, |g| {
        let lm = SynthLm::tiny(5);
        let n = 4 + g.rng.index(5);
        let trace = Trace::generate(&dense_spec(n, 4.0, 16, 32 + g.rng.index(3) * 8), g.case_seed);
        let lanes = [1usize, 2, 8, 32][g.rng.index(4)];
        let fetch = if g.rng.next_f64() < 0.5 {
            FetchMode::Batched
        } else {
            FetchMode::PerSequence
        };
        let codec = if g.rng.next_f64() < 0.5 {
            Codec::Lz4
        } else {
            Codec::Zstd
        };
        let chaos = 2 + g.rng.index(3) as u64;
        // tight enough to clamp sometimes, slack enough to finish
        let budget = [9500u64, 16 * 1024, 1 << 20][g.rng.index(3)];
        let cfg = SchedConfig { codec, fetch, ..SchedConfig::compressed(budget) };
        let sync = serve(&lm, &trace, &cfg, 1);
        let pcfg = SchedConfig { prefetch: true, prefetch_chaos: chaos, ..cfg };
        let pf = serve(&lm, &trace, &pcfg, lanes);
        let tag = format!("{codec}/{fetch:?}/{lanes} lanes/chaos={chaos}/budget={budget}");
        assert_serve_identical(&tag, &sync, &pf);
        let m = &pf.1;
        if m.prefetch_wasted_bytes == 0 || m.prefetch_misses == 0 {
            return Err(format!(
                "{tag}: chaos must force discarded speculation (wasted={} misses={})",
                m.prefetch_wasted_bytes, m.prefetch_misses
            ));
        }
        Ok(())
    });
}

#[test]
fn overlap_beats_synchronous_latency_at_high_concurrency() {
    // The headline perf claim, pinned at test scale before the bench
    // gates it: with 8+ concurrently active sequences and a hit rate
    // above zero, the modeled overlapped step latency undercuts the
    // synchronous model — while responses stay bit-identical.
    let lm = SynthLm::tiny(5);
    let trace = Trace::generate(&dense_spec(20, 4.0, 16, 32), 7);
    let cfg = SchedConfig::compressed(1 << 20);
    let sync = serve(&lm, &trace, &cfg, 8);
    assert!(
        sync.0.peak_active >= 8,
        "trace must reach 8+ concurrent actives, got {}",
        sync.0.peak_active
    );
    let pcfg = SchedConfig { prefetch: true, ..cfg };
    let pf = serve(&lm, &trace, &pcfg, 8);
    assert_serve_identical("8-active overlap", &sync, &pf);
    let m = &pf.1;
    assert!(m.steps_8plus > 0, "latency buckets never saw 8+ actives");
    assert!(m.prefetch_hit_rate() > 0.0, "no hits at high concurrency");
    assert!(
        m.overlapped_fetch_ns_8plus < m.sync_fetch_ns_8plus,
        "overlapped step latency ({}) must beat synchronous ({}) at 8+ actives",
        m.overlapped_fetch_ns_8plus,
        m.sync_fetch_ns_8plus
    );
    // prefetch off ⇒ the two figures are recorded equal by construction
    assert_eq!(sync.1.overlapped_fetch_ns.to_bits(), sync.1.sync_fetch_ns.to_bits());
}
