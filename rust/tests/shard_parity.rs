//! Shard-parity differential suite for sharded multi-controller serving
//! (`SchedConfig::shards`): with cross-shard stealing on (the default),
//! sharding is *placement-only* — the solo admission ladder over the
//! aggregate budget decides WHO runs and sharding decides only WHERE —
//! so a sharded serve must be **bit-identical** to the solo path at
//! {2, 4, 8} shards × {1, 8, 32} lanes × fetch modes × prefetch on/off
//! × sharing on/off, under a budget tight enough to engage the pressure
//! clamp and force evict/resume cycles: responses, read/page digests,
//! schedule events, recovery counters, the schedule digest, and the
//! full flight digest once the advisory `ShardSteer`/`ShardSteal`
//! records (the only permitted stream difference) are filtered out.
//! Per-shard attribution must conserve: the `shard_usage` entries sum
//! bit-exactly to the global `attributed` totals, and the modeled
//! channel-overlapped DRAM time never exceeds the serial model.
//!
//! The payoff side is pinned as a seeded property: on skew-heavy
//! workloads at equal aggregate budget, work stealing never serves
//! fewer sequences than static home-shard assignment (`steal = false`),
//! and beats it on at least one sampled case (non-vacuity).

use std::cell::Cell;
use std::sync::Arc;

use camc::coordinator::{
    serve_trace, EventKind, FetchMode, SchedConfig, SchedOutcome, ServeMetrics, TenantUsage,
    TrafficResponse,
};
use camc::engine::LaneArray;
use camc::obs::{EventKind as ObsKind, FlightRecording, RecorderCfg};
use camc::quant::policy::KvPolicy;
use camc::util::check::check;
use camc::workload::arrival::ArrivalProcess;
use camc::workload::lengths::LengthDist;
use camc::workload::synthmodel::SynthLm;
use camc::workload::tenant::{TenantSpec, WorkloadSpec};
use camc::workload::trace::Trace;

/// Dense uniform-random workload (no shared prefixes): every request is
/// unique content, so the sharing legs of the matrix exercise the
/// content-address path without dedup moving any bytes.
fn dense_spec(n: usize, rate: f64, prompt: usize, output: usize) -> WorkloadSpec {
    WorkloadSpec {
        arrival: ArrivalProcess::Poisson { rate },
        tenants: vec![TenantSpec {
            name: "t".into(),
            weight: 1.0,
            policy: KvPolicy::Full,
            prompt: LengthDist::Fixed(prompt),
            output: LengthDist::Fixed(output),
        }],
        n_requests: n,
        vocab: 256,
        max_seq: 128,
        shared_prefixes: vec![],
    }
}

/// Everything deterministic about a response (wall time excluded).
fn key(r: &TrafficResponse) -> (u64, Vec<u16>, u64, u64, u64, u64, u32, u64) {
    (
        r.id,
        r.tokens.clone(),
        r.mean_nll.to_bits(),
        r.kv_fetched_bytes,
        r.kv_pages_digest,
        r.read_digest,
        r.evictions,
        r.recovered_faults,
    )
}

fn serve(
    lm: &SynthLm,
    trace: &Trace,
    cfg: &SchedConfig,
    lanes: usize,
) -> (SchedOutcome, ServeMetrics) {
    let la = Arc::new(LaneArray::new(lanes));
    let mut m = ServeMetrics::default();
    let cfg = SchedConfig { collect_digests: true, ..cfg.clone() };
    let out = serve_trace(lm, trace, &cfg, la, &mut m).expect("serve_trace");
    (out, m)
}

fn is_shard_advisory(k: &ObsKind) -> bool {
    matches!(k, ObsKind::ShardSteer { .. } | ObsKind::ShardSteal { .. })
}

/// The recording with the shard placement advisories removed — the only
/// records a sharded run may add to the solo stream.
fn strip_shard_advisories(f: &FlightRecording) -> (FlightRecording, usize) {
    let events: Vec<_> = f
        .events
        .iter()
        .filter(|e| !is_shard_advisory(&e.kind))
        .copied()
        .collect();
    let stripped = f.events.len() - events.len();
    (FlightRecording { events }, stripped)
}

/// The integer-domain halves of both runs must match exactly; the f64
/// latency sums tolerate last-bit merge-order drift only.
fn assert_serve_identical(
    tag: &str,
    solo: &(SchedOutcome, ServeMetrics),
    sharded: &(SchedOutcome, ServeMetrics),
) {
    let ((base, bm), (o, m)) = (solo, sharded);
    assert_eq!(o.events, base.events, "{tag}: schedule diverged");
    assert_eq!(o.peak_active, base.peak_active, "{tag}");
    assert_eq!(o.steps, base.steps, "{tag}");
    assert_eq!(o.pressure_steps, base.pressure_steps, "{tag}");
    assert_eq!(
        o.responses.iter().map(key).collect::<Vec<_>>(),
        base.responses.iter().map(key).collect::<Vec<_>>(),
        "{tag}: responses diverged"
    );
    assert_eq!(m.steps, bm.steps, "{tag}");
    assert_eq!(m.fetched_bytes, bm.fetched_bytes, "{tag}: fetched bytes");
    assert_eq!(m.fetch_frames, bm.fetch_frames, "{tag}: fetched frames");
    assert_eq!(m.fetch_dispatches, bm.fetch_dispatches, "{tag}: dispatches");
    assert_eq!(m.host_copy_bytes, bm.host_copy_bytes, "{tag}: host copies");
    assert_eq!(m.tenants, bm.tenants, "{tag}: per-tenant stats");
    assert_eq!(m.tenant_usage, bm.tenant_usage, "{tag}: tenant attribution");
    assert_eq!(m.attributed, bm.attributed, "{tag}: attributed totals");
    // recovery counters (all zero on this fault-free matrix, pinned so
    // a sharded run can never silently quarantine)
    assert_eq!(
        (m.faults_injected, m.retries, m.parity_repairs, m.salvaged_reads, m.quarantined_seqs),
        (
            bm.faults_injected,
            bm.retries,
            bm.parity_repairs,
            bm.salvaged_reads,
            bm.quarantined_seqs
        ),
        "{tag}: recovery counters diverged"
    );
    assert_eq!(
        (m.dedup_pages, m.dedup_bytes_saved, m.cow_copies, m.unique_bytes),
        (bm.dedup_pages, bm.dedup_bytes_saved, bm.cow_copies, bm.unique_bytes),
        "{tag}: sharing counters diverged"
    );
    let rel = (m.sync_fetch_ns - bm.sync_fetch_ns).abs() / bm.sync_fetch_ns.max(1.0);
    assert!(
        rel < 1e-9,
        "{tag}: modeled sync latency drifted: {} vs {}",
        m.sync_fetch_ns,
        bm.sync_fetch_ns
    );
}

/// Per-shard attribution conservation: the shard entries sum bit-exactly
/// to the attributed totals and every key is a live shard index.
fn assert_shard_conservation(tag: &str, m: &ServeMetrics, nshards: usize) {
    let mut sum = TenantUsage::default();
    for (&s, u) in &m.shard_usage {
        assert!((s as usize) < nshards, "{tag}: shard key {s} out of range");
        sum.add(u);
    }
    assert_eq!(sum, m.attributed, "{tag}: shard attribution does not conserve");
}

#[test]
fn sharded_steal_serve_is_bit_identical_to_solo() {
    // The acceptance matrix: under a budget tight enough to clamp AND
    // force evict/resume cycles (pinned non-vacuous below), a sharded
    // serve with stealing on equals the solo serve bit-for-bit at every
    // shard count, lane count, fetch mode, prefetch and sharing
    // setting. The flight streams may differ ONLY by the advisory
    // ShardSteer/ShardSteal records; the schedule digest never moves.
    let lm = SynthLm::tiny(5);
    let trace = Trace::generate(&dense_spec(8, 8.0, 16, 48), 31);
    let budget = 9500u64;
    let advisories_seen = Cell::new(0usize);
    for fetch in [FetchMode::Batched, FetchMode::PerSequence] {
        for prefetch in [false, true] {
            for sharing in [false, true] {
                let cfg = SchedConfig {
                    fetch,
                    prefetch,
                    sharing,
                    record: Some(RecorderCfg::default()),
                    ..SchedConfig::compressed(budget)
                };
                let base = serve(&lm, &trace, &cfg, 1);
                assert_eq!(base.0.responses.len(), 8, "all requests complete");
                assert!(
                    base.0.events.iter().any(|e| e.kind == EventKind::Evict),
                    "{fetch:?}: budget must force evictions or the test is vacuous"
                );
                assert!(
                    base.0.pressure_steps[1] + base.0.pressure_steps[2] > 0,
                    "{fetch:?}: budget must engage the pressure clamp"
                );
                let bf = base.0.flight.as_ref().expect("recorder on");
                assert!(
                    !bf.events.iter().any(|e| is_shard_advisory(&e.kind)),
                    "solo run must emit no shard advisories"
                );
                // solo attribution lands entirely on shard 0
                assert!(
                    base.1.shard_usage.keys().all(|&s| s == 0),
                    "solo shard_usage must be keyed by shard 0 only"
                );
                assert_shard_conservation("solo", &base.1, 1);
                for shards in [2usize, 4, 8] {
                    for lanes in [1usize, 8, 32] {
                        let scfg = SchedConfig { shards, ..cfg.clone() };
                        let sh = serve(&lm, &trace, &scfg, lanes);
                        let tag =
                            format!("{fetch:?}/prefetch={prefetch}/sharing={sharing}/{shards} shards/{lanes} lanes");
                        assert_serve_identical(&tag, &base, &sh);
                        assert_shard_conservation(&tag, &sh.1, shards);
                        // channels overlap: the per-step max over shards
                        // can never exceed the serial (solo) model
                        assert!(
                            sh.1.channel_overlapped_ps <= base.1.channel_overlapped_ps,
                            "{tag}: overlapped {} ps > serial {} ps",
                            sh.1.channel_overlapped_ps,
                            base.1.channel_overlapped_ps
                        );
                        let sf = sh.0.flight.as_ref().expect("recorder on");
                        assert_eq!(
                            sf.schedule_digest(),
                            bf.schedule_digest(),
                            "{tag}: schedule digest diverged"
                        );
                        let (stripped, n_adv) = strip_shard_advisories(sf);
                        advisories_seen.set(advisories_seen.get() + n_adv);
                        assert_eq!(
                            stripped.digest(),
                            bf.digest(),
                            "{tag}: flight digest diverged beyond shard advisories"
                        );
                    }
                }
            }
        }
    }
    assert!(
        advisories_seen.get() > 0,
        "no sharded run ever steered/stole — the advisory-stream claim is vacuous"
    );
}

#[test]
fn one_shard_is_bit_identical_in_both_steal_modes() {
    // shards = 1 must be the pre-sharding path exactly, with stealing
    // on or off: identical schedule, responses, metrics, AND the full
    // flight digest (no advisory records exist to strip).
    let lm = SynthLm::tiny(5);
    let trace = Trace::generate(&dense_spec(8, 8.0, 16, 48), 31);
    let cfg = SchedConfig {
        record: Some(RecorderCfg::default()),
        ..SchedConfig::compressed(9500)
    };
    let base = serve(&lm, &trace, &cfg, 8);
    let bf = base.0.flight.as_ref().expect("recorder on");
    for steal in [true, false] {
        let scfg = SchedConfig { shards: 1, steal, ..cfg.clone() };
        let solo = serve(&lm, &trace, &scfg, 8);
        let tag = format!("1 shard, steal={steal}");
        assert_serve_identical(&tag, &base, &solo);
        let sf = solo.0.flight.as_ref().expect("recorder on");
        assert_eq!(sf.digest(), bf.digest(), "{tag}: flight digest diverged");
        assert_eq!(
            solo.1.channel_overlapped_ps, base.1.channel_overlapped_ps,
            "{tag}: at one shard the overlap model IS the serial model"
        );
    }
}

#[test]
fn work_stealing_never_serves_fewer_property() {
    // The payoff property at equal aggregate budget: on random
    // skew-heavy workloads (whale prompts next to light chat), within a
    // fixed virtual-step horizon, cross-shard stealing completes at
    // least as many sequences as static home-shard assignment — a
    // steered admission can only use capacity the static wall strands.
    // At least one sampled case must show a strict win (non-vacuity).
    let strict_wins = Cell::new(0u64);
    check("steal_never_serves_fewer", 12, |g| {
        let lm = SynthLm::tiny(5);
        let n = 10 + g.rng.index(9);
        let rate = 1.0 + g.rng.next_f64() * 2.0;
        let spec = WorkloadSpec::skewed_whales(ArrivalProcess::Poisson { rate }, n, 128);
        let trace = Trace::generate(&spec, g.case_seed);
        let budget = [12 * 1024u64, 16 * 1024, 24 * 1024][g.rng.index(3)];
        let shards = [2usize, 4, 8][g.rng.index(3)];
        let horizon = 64 + g.rng.index(5) as u64 * 16;
        let cfg = |steal: bool| SchedConfig {
            shards,
            steal,
            max_steps: horizon,
            ..SchedConfig::compressed(budget)
        };
        let (steal_out, _) = serve(&lm, &trace, &cfg(true), 8);
        let (static_out, _) = serve(&lm, &trace, &cfg(false), 8);
        if steal_out.responses.len() < static_out.responses.len() {
            return Err(format!(
                "stealing served fewer: {} vs {} (n={n} budget={budget} shards={shards} horizon={horizon})",
                steal_out.responses.len(),
                static_out.responses.len()
            ));
        }
        if steal_out.responses.len() > static_out.responses.len() {
            strict_wins.set(strict_wins.get() + 1);
        }
        Ok(())
    });
    assert!(
        strict_wins.get() > 0,
        "stealing never beat the static wall on any sampled case — the property is vacuous"
    );
}
