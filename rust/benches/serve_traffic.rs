//! Traffic-serving bench: the continuous-batching scheduler under a
//! seeded open-loop Poisson workload, hermetic on the synthetic decode
//! backend. Prints the admission-policy comparison AND writes
//! `BENCH_serve.json` so the traffic profile joins the perf trajectory
//! next to `BENCH_hotpath.json`.
//!
//!     cargo bench --bench serve_traffic [-- --fast] [-- --check]
//!
//! `--fast` trims the trace/horizon for CI smoke runs; `--check` exits
//! non-zero if pressure-driven admission serves fewer sequences than
//! fixed-slot admission at equal byte budget, if the compressed budget
//! fails to sustain more concurrency than the byte-equal uncompressed
//! budget, if the zero-materialization view path's per-step host copy
//! bytes stop beating the materializing copy-plan baseline, if the
//! fault-injection row pair stops resolving every recovery-ladder rung
//! with fault-untouched sequences byte-identical to the fault-free run,
//! if the predictive prefetch engine stops serving a byte-identical
//! schedule with hit rate > 0 and a modeled overlapped step-fetch
//! latency below the synchronous model at 8+ concurrent actives, or if
//! the flight recorder stops being invisible (recorder-on must serve
//! the byte-identical schedule of the recorder-off run, recorder-off
//! must leave no recording), if the per-tenant attribution stops summing
//! exactly to the global fetch/host-copy counters, or if
//! content-addressed page sharing serves fewer sequences than
//! sharing-off at equal budget on the shared-prefix mix, stops
//! deduplicating bytes there, or stops being bit-identical to
//! sharing-off on the prefix-free mix, if sharded serving at 2+ memory
//! controllers stops serving at least the solo count at equal aggregate
//! budget, if served-sequence throughput per modeled DRAM time stops
//! increasing monotonically across the {1, 2, 4}-shard sweep, or if
//! cross-shard work stealing stops admitting strictly more than static
//! home-shard assignment on the skew-heavy whale mix (the regressions
//! CI gates on).
//! Also writes the recorder-on run's event stream as
//! `FLIGHT_serve.trace.json` (Perfetto) + `FLIGHT_serve.bin`
//! (`CAMCEVT1`) for the CI flight-recorder artifact.

use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Instant;

use camc::coordinator::{
    fixed_slots_for_budget, serve_trace, EventKind, FetchMode, MaterializedRef, SchedConfig,
    SchedOutcome, ServeMetrics, StepModel, TenantUsage, TrafficResponse,
};
use camc::engine::LaneArray;
use camc::memctrl::FaultPlan;
use camc::obs::RecorderCfg;
use camc::report::{BenchReport, Table};
use camc::workload::{ArrivalProcess, LengthDist, PrefixFamily, SynthLm, Trace, WorkloadSpec};

fn run_with<M: StepModel>(
    lm: &M,
    trace: &Trace,
    cfg: &SchedConfig,
) -> (SchedOutcome, ServeMetrics, f64) {
    let lanes = Arc::new(LaneArray::with_default_lanes());
    let mut m = ServeMetrics::default();
    let t0 = Instant::now();
    let out = serve_trace(lm, trace, cfg, lanes, &mut m).expect("serve_trace");
    (out, m, t0.elapsed().as_secs_f64())
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let fast = args.iter().any(|a| a == "--fast");
    let check = args.iter().any(|a| a == "--check");

    let lm = SynthLm::tiny(2026);
    let n_requests = if fast { 28 } else { 72 };
    let horizon: u64 = if fast { 220 } else { 520 };
    let spec = WorkloadSpec::chat_plus_batch(
        ArrivalProcess::Poisson { rate: 1.2 },
        n_requests,
        lm.meta.max_seq,
    );
    let trace = Trace::generate(&spec, 7);
    // a KV tier worth ~6 worst-case raw sequences
    let budget: u64 = 6 * 16 * 1024;

    let mut report = BenchReport::new();
    let run =
        |cfg: &SchedConfig| -> (SchedOutcome, ServeMetrics, f64) { run_with(&lm, &trace, cfg) };
    let capped = |mut cfg: SchedConfig| -> SchedConfig {
        cfg.max_steps = horizon;
        cfg
    };

    // equal-budget comparison within a fixed virtual horizon: how many
    // sequences does each admission policy actually serve?
    let (fx, _, _) = run(&capped(SchedConfig::fixed_slots(fixed_slots_for_budget(
        budget, &lm.meta,
    ))));
    let (un, _, _) = run(&capped(SchedConfig::uncompressed(budget)));
    let (co, cm, cwall) = run(&capped(SchedConfig::compressed(budget)));
    // the same admission with the per-sequence (one-load-per-page)
    // reference fetch: identical schedule by construction, more lane
    // dispatches — the regression surface for the batched decode path
    let (ps, psm, pwall) = run(&capped(SchedConfig {
        fetch: FetchMode::PerSequence,
        ..SchedConfig::compressed(budget)
    }));
    // the materializing (copy-plan) reference: same admission/schedule,
    // dense degraded K/V copies per step — the host-copy-bytes baseline
    let (mat, matm, _) = run_with(
        &MaterializedRef(&lm),
        &trace,
        &capped(SchedConfig::compressed(budget)),
    );
    // wall-rate row: the full trace, uncapped, compressed admission
    let (full, fm, wall) = run(&SchedConfig::compressed(budget));

    // fault-injection row pair: the same trace under a seeded FaultPlan,
    // without and with the XOR parity plane. Run at a slack budget (no
    // pressure clamps, no evictions) so the only divergence from the
    // fault-free baseline is the faults themselves — which makes the
    // byte-identity claim exact: every sequence the plan never touched
    // (recovered_faults == 0, not quarantined) must match its baseline
    // response byte-for-byte.
    let slack: u64 = 1 << 20;
    let digests = |parity: bool, faults: Option<Arc<FaultPlan>>| -> SchedConfig {
        capped(SchedConfig {
            collect_digests: true,
            parity,
            faults,
            ..SchedConfig::compressed(slack)
        })
    };
    let plan = Arc::new(FaultPlan {
        seed: 2026,
        p_plane_flip: 120,
        p_header_flip: 8,
        p_transient: 50,
        p_lane_fault: 30,
        flip_plane: None,
    });
    // parity changes every stored frame (and so every fault-site address):
    // each faulty row compares against the fault-free run of its OWN
    // geometry
    let (base_np, base_npm, _) = run(&digests(false, None));
    let (base_pa, _, _) = run(&digests(true, None));
    let (f_np, fnpm, _) = run(&digests(false, Some(Arc::clone(&plan))));
    let (f_pa, fpam, _) = run(&digests(true, Some(Arc::clone(&plan))));
    // (unaffected matched, of those byte-identical) vs the baseline
    let survivors = |faulty: &SchedOutcome, base: &SchedOutcome| -> (u64, u64) {
        let by_id: BTreeMap<u64, &TrafficResponse> =
            base.responses.iter().map(|r| (r.id, r)).collect();
        let (mut unaffected, mut identical) = (0u64, 0u64);
        for r in &faulty.responses {
            if r.recovered_faults != 0 {
                continue;
            }
            let Some(b) = by_id.get(&r.id) else { continue };
            unaffected += 1;
            if r.tokens == b.tokens
                && r.mean_nll == b.mean_nll
                && r.kv_pages_digest == b.kv_pages_digest
                && r.read_digest == b.read_digest
                && r.kv_fetched_bytes == b.kv_fetched_bytes
            {
                identical += 1;
            }
        }
        (unaffected, identical)
    };
    let (np_unaffected, np_identical) = survivors(&f_np, &base_np);
    let (pa_unaffected, pa_identical) = survivors(&f_pa, &base_pa);

    // prefetch row: the same slack-budget digest run with the predictive
    // prefetch engine on. The serve must stay byte-identical to `base_np`
    // (schedule + responses — tests/prefetch_parity.rs pins the full
    // matrix; the bench re-proves it on the bench workload), while the
    // modeled overlapped step-fetch latency undercuts the synchronous
    // model wherever 8+ sequences are concurrently active.
    let (pre, prem, _) = run(&SchedConfig {
        prefetch: true,
        ..digests(false, None)
    });
    let same_serve = |a: &SchedOutcome, b: &SchedOutcome| -> bool {
        a.events == b.events
            && a.responses.len() == b.responses.len()
            && a.responses.iter().zip(&b.responses).all(|(x, y)| {
                x.id == y.id
                    && x.tokens == y.tokens
                    && x.mean_nll == y.mean_nll
                    && x.kv_pages_digest == y.kv_pages_digest
                    && x.read_digest == y.read_digest
                    && x.kv_fetched_bytes == y.kv_fetched_bytes
            })
    };
    let prefetch_identical = same_serve(&pre, &base_np);
    let mean_8plus = |ns: f64| -> f64 {
        if prem.steps_8plus == 0 {
            0.0
        } else {
            ns / prem.steps_8plus as f64
        }
    };

    // flight-recorder row: the same digest run with the recorder on. The
    // recorder is written to, never read — recorder-on must serve a
    // byte-identical schedule and responses, recorder-off must leave no
    // recording behind, and the per-tenant attribution must sum
    // bit-exactly to the global fetch/host-copy counters (the
    // conservation law tests/obs_parity.rs pins across the full matrix).
    let (fr, frm, _) = run(&SchedConfig {
        record: Some(RecorderCfg::default()),
        ..digests(false, None)
    });
    let recorder_identical = same_serve(&fr, &base_np)
        && frm.fetched_bytes == base_npm.fetched_bytes
        && frm.fetch_frames == base_npm.fetch_frames
        && frm.fetch_dispatches == base_npm.fetch_dispatches
        && frm.host_copy_bytes == base_npm.host_copy_bytes
        && frm.attributed == base_npm.attributed
        && frm.tenant_usage == base_npm.tenant_usage;
    let flight = fr
        .flight
        .as_ref()
        .expect("recorder-on serve returns a flight recording");
    let mut tenant_sum = TenantUsage::default();
    for u in frm.tenant_usage.values() {
        tenant_sum.add(u);
    }
    let conserved = frm.attributed.dram_bytes == frm.fetched_bytes
        && frm.attributed.lane_frames == frm.fetch_frames
        && frm.attributed.host_copy_bytes == frm.host_copy_bytes
        && tenant_sum == frm.attributed;

    // content-addressed sharing row pair: the same chat+batch mix with
    // the chat tenant reshaped prefix-heavy — prompts of 16..=32 tokens,
    // 90% of them opening with one shared 32-token system-prompt family
    // (>= one full KV page of identical content per member) — served
    // sharing-on vs sharing-off at the SAME compressed budget and
    // horizon. Sharing charges each sequence only its unique compressed
    // bytes, so the shared prefix stops double-billing admission: the
    // dedup'd capacity converts directly into served sequences. The
    // prefix-free leg re-proves invisibility on the bench trace itself:
    // sharing-on must stay byte-identical to `base_np` with zero dedup
    // activity (tests/sharing_parity.rs pins the full matrix).
    let mut shared_spec = spec.clone();
    shared_spec.tenants[0].prompt = LengthDist::Uniform { lo: 16, hi: 32 };
    shared_spec.shared_prefixes = vec![PrefixFamily {
        tenant: 0,
        tokens: 32,
        prob: 900,
        seed: 11,
    }];
    let shared_trace = Trace::generate(&shared_spec, 7);
    let sharing_cfg = |sharing: bool| -> SchedConfig {
        capped(SchedConfig {
            sharing,
            collect_digests: true,
            ..SchedConfig::compressed(budget)
        })
    };
    let (sh_off, _, _) = run_with(&lm, &shared_trace, &sharing_cfg(false));
    let (sh_on, shm, _) = run_with(&lm, &shared_trace, &sharing_cfg(true));
    let (sh_base, shbm, _) = run(&SchedConfig {
        sharing: true,
        ..digests(false, None)
    });
    let sharing_invisible = same_serve(&sh_base, &base_np)
        && shbm.dedup_pages == 0
        && shbm.dedup_bytes_saved == 0
        && shbm.cow_copies == 0;

    // sharded memory-controller sweep: the bursty chat+batch mix at the
    // SAME aggregate compressed budget partitioned across {1, 2, 4}
    // shards with cross-shard stealing on. Placement-only sharding:
    // every shard count serves the bit-identical schedule (the parity
    // tests/shard_parity.rs pins), while the modeled per-step DRAM time
    // drops to the max over channels — so served-sequence throughput
    // per modeled DRAM second rises monotonically with the channel
    // count. The steal-vs-static pair on a skew-heavy whale mix shows
    // what cross-shard admission buys: the static home-slice wall
    // strands budget behind hash-collided whales, stealing converts it
    // into served sequences.
    let shard_cfg = |n: usize, steal: bool| -> SchedConfig {
        capped(SchedConfig {
            shards: n,
            steal,
            ..SchedConfig::compressed(budget)
        })
    };
    let shard_runs: Vec<(usize, SchedOutcome, ServeMetrics)> = [1usize, 2, 4]
        .into_iter()
        .map(|n| {
            let (o, m, _) = run(&shard_cfg(n, true));
            (n, o, m)
        })
        .collect();
    // served sequences per modeled DRAM millisecond — the quantity the
    // shard-scaling gate requires to rise 1 -> 2 -> 4
    let shard_tput = |served: usize, m: &ServeMetrics| -> f64 {
        served as f64 / (m.channel_overlapped_ns() / 1e6).max(1e-9)
    };
    let skew_spec = WorkloadSpec::skewed_whales(
        ArrivalProcess::Poisson { rate: 1.0 },
        if fast { 24 } else { 48 },
        lm.meta.max_seq,
    );
    let skew_trace = Trace::generate(&skew_spec, 13);
    // a tight budget (slices of budget/4) so whale footprints collide
    // on their home slices — the regime stealing exists for
    let skew_budget: u64 = 2 * 16 * 1024;
    let skew_cfg = |steal: bool| -> SchedConfig {
        capped(SchedConfig {
            shards: 4,
            steal,
            ..SchedConfig::compressed(skew_budget)
        })
    };
    let (steal_out, _, _) = run_with(&lm, &skew_trace, &skew_cfg(true));
    let (static_out, _, _) = run_with(&lm, &skew_trace, &skew_cfg(false));

    let evicts = |o: &SchedOutcome| {
        o.events
            .iter()
            .filter(|e| e.kind == EventKind::Evict)
            .count()
    };
    let mut tab = Table::new(
        &format!("traffic @ {budget} B KV budget, horizon {horizon} steps"),
        &["admission", "served", "peak conc", "evicts", "ttft p99", "e2e p99"],
    );
    for (name, o, m) in [
        ("fixed-slot", &fx, None),
        ("budget uncompressed", &un, None),
        ("budget compressed", &co, Some(&cm)),
        ("  + per-seq fetch", &ps, Some(&psm)),
    ] {
        tab.row(&[
            name.into(),
            o.responses.len().to_string(),
            o.peak_active.to_string(),
            evicts(o).to_string(),
            m.map(|m| format!("{:.0}", m.ttft_steps_p(0.99)))
                .unwrap_or_else(|| "-".into()),
            m.map(|m| format!("{:.0}", m.e2e_steps_p(0.99)))
                .unwrap_or_else(|| "-".into()),
        ]);
    }
    tab.print();
    println!(
        "full trace (compressed, uncapped): {} requests in {} virtual steps, {:.1} steps/s, {:.0} tok/s wall",
        full.responses.len(),
        full.steps,
        full.steps as f64 / wall,
        fm.tokens_per_sec(wall)
    );
    println!(
        "decode fetch: batched {:.1} frames/dispatch vs per-seq {:.1} ({:.0} KiB fetched, {:.2}x wall)",
        cm.fetch_frames_per_dispatch(),
        psm.fetch_frames_per_dispatch(),
        cm.fetched_bytes as f64 / 1024.0,
        pwall / cwall.max(1e-9)
    );
    println!(
        "read path host copies: view {:.0} B/step vs materialized {:.0} B/step ({:.1}x less)",
        cm.host_copy_bytes_per_step(),
        matm.host_copy_bytes_per_step(),
        matm.host_copy_bytes as f64 / cm.host_copy_bytes.max(1) as f64
    );
    println!(
        "fault run (no parity): {} served / {} quarantined — {} faults: {} retries, {} salvaged; unaffected {}/{} byte-identical to fault-free",
        f_np.responses.len(),
        fnpm.quarantined_seqs,
        fnpm.faults_injected,
        fnpm.retries,
        fnpm.salvaged_reads,
        np_identical,
        np_unaffected,
    );
    println!(
        "fault run (parity):    {} served / {} quarantined — {} faults: {} retries, {} repaired in place; unaffected {}/{} byte-identical to fault-free",
        f_pa.responses.len(),
        fpam.quarantined_seqs,
        fpam.faults_injected,
        fpam.retries,
        fpam.parity_repairs,
        pa_identical,
        pa_unaffected,
    );
    println!(
        "prefetch: {:.0}% hit rate ({} issued, {} wasted B) — step fetch {:.0} ns sync vs {:.0} ns overlapped at 8+ active ({} steps), byte-identical: {}",
        prem.prefetch_hit_rate() * 100.0,
        prem.prefetch_issued,
        prem.prefetch_wasted_bytes,
        mean_8plus(prem.sync_fetch_ns_8plus),
        mean_8plus(prem.overlapped_fetch_ns_8plus),
        prem.steps_8plus,
        prefetch_identical,
    );
    println!(
        "flight recorder: {} events ({} dropped), digest {:016x} — invisible: {}, attribution conserved: {} ({} tenants, {:.0} pJ modeled DRAM)",
        flight.events.len(),
        flight.dropped(),
        flight.digest(),
        recorder_identical,
        conserved,
        frm.tenant_usage.len(),
        frm.attributed.energy_pj(),
    );
    println!(
        "prefix sharing: served {} vs {} without, {} pages dedup'd ({} B saved, {} B unique, {} CoW) — prefix-free bit-identical: {}",
        sh_on.responses.len(),
        sh_off.responses.len(),
        shm.dedup_pages,
        shm.dedup_bytes_saved,
        shm.unique_bytes,
        shm.cow_copies,
        sharing_invisible,
    );

    let mut shtab = Table::new(
        "shard scaling (same aggregate budget, steal on)",
        &[
            "shards",
            "served",
            "serial dram ns",
            "overlapped ns",
            "served/modeled ms",
        ],
    );
    for (n, o, m) in &shard_runs {
        shtab.row(&[
            n.to_string(),
            o.responses.len().to_string(),
            format!("{:.0}", m.attributed.dram_ns()),
            format!("{:.0}", m.channel_overlapped_ns()),
            format!("{:.1}", shard_tput(o.responses.len(), m)),
        ]);
    }
    shtab.print();
    println!(
        "shard admission (skew whale mix, 4 shards @ {skew_budget} B): steal served {} vs static {}",
        steal_out.responses.len(),
        static_out.responses.len()
    );

    report.insert(
        "serve_traffic steps_per_sec",
        (full.steps as f64 / wall).round(),
    );
    report.insert(
        "serve_traffic tokens_per_sec",
        fm.tokens_per_sec(wall).round(),
    );
    report.insert(
        "served sequences (pressure, compressed)",
        co.responses.len() as f64,
    );
    report.insert(
        "served sequences (budget, uncompressed)",
        un.responses.len() as f64,
    );
    report.insert("served sequences (fixed-slot)", fx.responses.len() as f64);
    report.insert("peak concurrency (compressed)", co.peak_active as f64);
    report.insert("peak concurrency (uncompressed)", un.peak_active as f64);
    report.insert("evictions (compressed)", evicts(&co) as f64);
    report.insert("ttft p99 steps", cm.ttft_steps_p(0.99));
    report.insert("tbt p99 steps", cm.tbt_steps_p(0.99));
    report.insert("e2e p99 steps", cm.e2e_steps_p(0.99));
    report.insert(
        "served sequences (batched fetch)",
        co.responses.len() as f64,
    );
    report.insert(
        "served sequences (per-seq fetch)",
        ps.responses.len() as f64,
    );
    report.insert(
        "fetch frames per dispatch (batched)",
        (cm.fetch_frames_per_dispatch() * 10.0).round() / 10.0,
    );
    report.insert(
        "fetch frames per dispatch (per-seq)",
        (psm.fetch_frames_per_dispatch() * 10.0).round() / 10.0,
    );
    report.insert("kv fetched bytes (batched)", cm.fetched_bytes as f64);
    report.insert(
        "host copy bytes per step (view)",
        cm.host_copy_bytes_per_step().round(),
    );
    report.insert(
        "host copy bytes per step (materialized)",
        matm.host_copy_bytes_per_step().round(),
    );
    report.insert(
        "recovery faults injected (no parity)",
        fnpm.faults_injected as f64,
    );
    report.insert("recovery retries (no parity)", fnpm.retries as f64);
    report.insert(
        "recovery salvaged reads (no parity)",
        fnpm.salvaged_reads as f64,
    );
    report.insert("recovery parity repairs (parity)", fpam.parity_repairs as f64);
    report.insert(
        "recovery quarantined seqs (no parity)",
        fnpm.quarantined_seqs as f64,
    );
    report.insert(
        "fault-run unaffected byte-identical (no parity)",
        np_identical as f64,
    );
    report.insert(
        "fault-run unaffected byte-identical (parity)",
        pa_identical as f64,
    );
    report.insert(
        "prefetch hit rate",
        (prem.prefetch_hit_rate() * 1000.0).round() / 1000.0,
    );
    report.insert("prefetch issued pages", prem.prefetch_issued as f64);
    report.insert("prefetch wasted bytes", prem.prefetch_wasted_bytes as f64);
    report.insert(
        "step fetch ns at 8plus (sync model)",
        mean_8plus(prem.sync_fetch_ns_8plus).round(),
    );
    report.insert(
        "step fetch ns at 8plus (overlapped)",
        mean_8plus(prem.overlapped_fetch_ns_8plus).round(),
    );
    report.insert(
        "step fetch ns mean (sync model)",
        prem.mean_sync_fetch_ns().round(),
    );
    report.insert(
        "step fetch ns mean (overlapped)",
        prem.mean_overlapped_fetch_ns().round(),
    );
    report.insert(
        "shared-prefix served (sharing)",
        sh_on.responses.len() as f64,
    );
    report.insert(
        "shared-prefix served (no sharing)",
        sh_off.responses.len() as f64,
    );
    report.insert("shared-prefix dedup pages", shm.dedup_pages as f64);
    report.insert(
        "shared-prefix dedup_bytes_saved",
        shm.dedup_bytes_saved as f64,
    );
    report.insert("shared-prefix unique_bytes", shm.unique_bytes as f64);
    report.insert("shared-prefix cow copies", shm.cow_copies as f64);
    report.insert(
        "sharing invisible on prefix-free mix",
        sharing_invisible as u64 as f64,
    );
    for (n, o, m) in &shard_runs {
        report.insert(
            &format!("served sequences ({n} shards)"),
            o.responses.len() as f64,
        );
        report.insert(
            &format!("channel overlapped ns ({n} shards)"),
            m.channel_overlapped_ns().round(),
        );
        report.insert(
            &format!("shard throughput per modeled ms ({n} shards)"),
            (shard_tput(o.responses.len(), m) * 10.0).round() / 10.0,
        );
    }
    report.insert(
        "skew served sequences (steal)",
        steal_out.responses.len() as f64,
    );
    report.insert(
        "skew served sequences (static)",
        static_out.responses.len() as f64,
    );
    report.insert("flight recorder events", flight.events.len() as f64);
    report.insert(
        "flight recorder invisible",
        recorder_identical as u64 as f64,
    );
    report.insert("tenant attribution conserved", conserved as u64 as f64);
    report.insert("tenants attributed", frm.tenant_usage.len() as f64);
    report.insert("attributed dram bytes", frm.attributed.dram_bytes as f64);
    report.insert(
        "attributed modeled energy pj",
        frm.attributed.energy_pj().round(),
    );

    std::fs::write("FLIGHT_serve.bin", flight.to_bytes()).expect("write FLIGHT_serve.bin");
    std::fs::write("FLIGHT_serve.trace.json", flight.to_perfetto())
        .expect("write FLIGHT_serve.trace.json");
    println!(
        "wrote FLIGHT_serve.trace.json + FLIGHT_serve.bin ({} events)",
        flight.events.len()
    );
    report.write("BENCH_serve.json");

    if check {
        let mut ok = true;
        if co.responses.len() < fx.responses.len() {
            eprintln!(
                "CHECK FAILED: pressure-driven admission served {} sequences, fixed-slot served {} (equal budget)",
                co.responses.len(),
                fx.responses.len()
            );
            ok = false;
        }
        if co.peak_active <= un.peak_active {
            eprintln!(
                "CHECK FAILED: compressed budget peak concurrency {} <= uncompressed {}",
                co.peak_active, un.peak_active
            );
            ok = false;
        }
        if co.responses.len() < ps.responses.len() {
            eprintln!(
                "CHECK FAILED: batched fetch served {} sequences, per-sequence fetch served {} (same admission)",
                co.responses.len(),
                ps.responses.len()
            );
            ok = false;
        }
        if cm.fetch_dispatches > psm.fetch_dispatches {
            eprintln!(
                "CHECK FAILED: batched fetch used {} dispatches, per-sequence {} — batching regressed",
                cm.fetch_dispatches, psm.fetch_dispatches
            );
            ok = false;
        }
        if mat.responses.len() != co.responses.len() {
            eprintln!(
                "CHECK FAILED: materialized reference served {} sequences, view path {} — \
                 the read path must not change the schedule",
                mat.responses.len(),
                co.responses.len()
            );
            ok = false;
        }
        // deterministic byte counts, not timings: the zero-materialization
        // path must copy strictly less host data per step than the
        // copy-plan baseline
        if cm.host_copy_bytes >= matm.host_copy_bytes {
            eprintln!(
                "CHECK FAILED: view path host copies {} B >= materializing baseline {} B",
                cm.host_copy_bytes, matm.host_copy_bytes
            );
            ok = false;
        }
        // recovery-ladder gates: the plan must actually fire, every rung
        // it documents must resolve at least one fault (retry + salvage
        // without parity, retry + in-place repair with parity — parity
        // must leave NOTHING to salvage), and every sequence the plan
        // never touched must be byte-identical to its fault-free baseline
        if fnpm.faults_injected == 0 || fpam.faults_injected == 0 {
            eprintln!(
                "CHECK FAILED: fault plan never fired (no-parity {} faults, parity {})",
                fnpm.faults_injected, fpam.faults_injected
            );
            ok = false;
        }
        if fnpm.retries == 0 || fpam.retries == 0 {
            eprintln!(
                "CHECK FAILED: retry rung never resolved a transient fault (no-parity {}, parity {})",
                fnpm.retries, fpam.retries
            );
            ok = false;
        }
        if fnpm.salvaged_reads == 0 {
            eprintln!("CHECK FAILED: no plane flip was salvaged on the no-parity run");
            ok = false;
        }
        if fpam.parity_repairs == 0 || fpam.salvaged_reads != 0 {
            eprintln!(
                "CHECK FAILED: parity run repaired {} planes but salvaged {} (must repair all, salvage none)",
                fpam.parity_repairs, fpam.salvaged_reads
            );
            ok = false;
        }
        if np_unaffected == 0 || np_identical != np_unaffected {
            eprintln!(
                "CHECK FAILED: no-parity fault run: {}/{} unaffected sequences byte-identical to the fault-free baseline",
                np_identical, np_unaffected
            );
            ok = false;
        }
        if pa_unaffected == 0 || pa_identical != pa_unaffected {
            eprintln!(
                "CHECK FAILED: parity fault run: {}/{} unaffected sequences byte-identical to the fault-free baseline",
                pa_identical, pa_unaffected
            );
            ok = false;
        }
        // prefetch gates: speculation must be invisible (byte-identical
        // serve), must actually hit, and must shrink the modeled
        // step-blocking fetch latency where 8+ sequences are active
        if !prefetch_identical {
            eprintln!("CHECK FAILED: prefetch-on serve diverged from the synchronous run");
            ok = false;
        }
        if prem.steps_8plus == 0 {
            eprintln!(
                "CHECK FAILED: bench workload never reached 8 concurrent actives — the overlap gate is vacuous"
            );
            ok = false;
        }
        if prem.prefetch_hit_rate() <= 0.0 {
            eprintln!("CHECK FAILED: prefetch hit rate is zero");
            ok = false;
        }
        if prem.overlapped_fetch_ns_8plus >= prem.sync_fetch_ns_8plus {
            eprintln!(
                "CHECK FAILED: overlapped step fetch {} ns >= synchronous model {} ns at 8+ actives",
                prem.overlapped_fetch_ns_8plus, prem.sync_fetch_ns_8plus
            );
            ok = false;
        }
        // flight-recorder gates: the recorder must be invisible
        // (recorder-on byte-identical to recorder-off, recorder-off run
        // returns no recording), must actually capture the serve, and
        // the per-tenant attribution must conserve exactly
        if !recorder_identical {
            eprintln!("CHECK FAILED: recorder-on serve diverged from the recorder-off run");
            ok = false;
        }
        if base_np.flight.is_some() || co.flight.is_some() {
            eprintln!("CHECK FAILED: recorder-off run returned a flight recording");
            ok = false;
        }
        if flight.events.is_empty() {
            eprintln!("CHECK FAILED: recorder-on run captured no events");
            ok = false;
        }
        if !conserved {
            eprintln!(
                "CHECK FAILED: tenant attribution does not conserve (attributed {} dram B / {} frames / {} host B vs globals {} / {} / {})",
                frm.attributed.dram_bytes,
                frm.attributed.lane_frames,
                frm.attributed.host_copy_bytes,
                frm.fetched_bytes,
                frm.fetch_frames,
                frm.host_copy_bytes
            );
            ok = false;
        }
        // sharing gates: on the prefix-heavy mix dedup must actually
        // reclaim capacity and that capacity must convert into at least
        // as many served sequences as sharing-off at the same budget; on
        // the prefix-free mix sharing must be invisible (byte-identical
        // serve, zero dedup activity)
        if sh_on.responses.len() < sh_off.responses.len() {
            eprintln!(
                "CHECK FAILED: sharing served {} sequences, sharing-off served {} (equal budget, shared-prefix mix)",
                sh_on.responses.len(),
                sh_off.responses.len()
            );
            ok = false;
        }
        if shm.dedup_bytes_saved == 0 || shm.dedup_pages == 0 {
            eprintln!(
                "CHECK FAILED: shared-prefix mix deduplicated {} pages / {} bytes — content addressing never fired",
                shm.dedup_pages, shm.dedup_bytes_saved
            );
            ok = false;
        }
        if !sharing_invisible {
            eprintln!(
                "CHECK FAILED: sharing-on diverged from sharing-off on the prefix-free mix ({} dedup pages, {} B saved, {} CoW)",
                shbm.dedup_pages, shbm.dedup_bytes_saved, shbm.cow_copies
            );
            ok = false;
        }
        // shard gates: 2+ shards must serve at least the solo count at
        // equal aggregate budget (placement-only sharding serves the
        // identical schedule), served-sequence throughput per modeled
        // DRAM time must rise strictly across the 1 -> 2 -> 4 sweep
        // (the channel-overlap win), and cross-shard stealing must
        // admit strictly more than static home-shard assignment on the
        // skew-heavy whale mix
        let solo_served = shard_runs[0].1.responses.len();
        for (n, o, _) in &shard_runs[1..] {
            if o.responses.len() < solo_served {
                eprintln!(
                    "CHECK FAILED: {n} shards served {} sequences, solo served {solo_served} (equal aggregate budget)",
                    o.responses.len()
                );
                ok = false;
            }
        }
        for w in shard_runs.windows(2) {
            let (na, ref oa, ref ma) = w[0];
            let (nb, ref ob, ref mb) = w[1];
            let (ta, tb) = (
                shard_tput(oa.responses.len(), ma),
                shard_tput(ob.responses.len(), mb),
            );
            if tb <= ta {
                eprintln!(
                    "CHECK FAILED: shard throughput not monotonic: {tb:.2} served/modeled-ms at {nb} shards <= {ta:.2} at {na}"
                );
                ok = false;
            }
        }
        if steal_out.responses.len() <= static_out.responses.len() {
            eprintln!(
                "CHECK FAILED: work stealing served {} sequences, static home-shard assignment served {} (skew mix)",
                steal_out.responses.len(),
                static_out.responses.len()
            );
            ok = false;
        }
        if !ok {
            std::process::exit(1);
        }
        println!(
            "check ✓ host copies view {} B < materialized {} B",
            cm.host_copy_bytes, matm.host_copy_bytes
        );
        println!(
            "check ✓ recovery ladder: {} + {} faults resolved ({} retried, {} salvaged, {} parity-repaired, {} + {} quarantined); unaffected byte-identical {}/{} and {}/{}",
            fnpm.faults_injected,
            fpam.faults_injected,
            fnpm.retries + fpam.retries,
            fnpm.salvaged_reads,
            fpam.parity_repairs,
            fnpm.quarantined_seqs,
            fpam.quarantined_seqs,
            np_identical,
            np_unaffected,
            pa_identical,
            pa_unaffected
        );
        println!(
            "check ✓ prefetch byte-identical at {:.0}% hit rate, step fetch {:.0} -> {:.0} ns at 8+ active ({} steps)",
            prem.prefetch_hit_rate() * 100.0,
            mean_8plus(prem.sync_fetch_ns_8plus),
            mean_8plus(prem.overlapped_fetch_ns_8plus),
            prem.steps_8plus
        );
        println!(
            "check ✓ flight recorder invisible ({} events, digest {:016x}); attribution conserved across {} tenants",
            flight.events.len(),
            flight.digest(),
            frm.tenant_usage.len()
        );
        println!(
            "check ✓ prefix sharing served {} >= {} at equal budget ({} pages / {} B dedup'd, {} B unique); invisible on prefix-free mix",
            sh_on.responses.len(),
            sh_off.responses.len(),
            shm.dedup_pages,
            shm.dedup_bytes_saved,
            shm.unique_bytes
        );
        println!(
            "check ✓ shard scaling: served {} at every count, throughput {:.1} -> {:.1} -> {:.1} served/modeled-ms across 1/2/4 shards; steal {} > static {} on the skew mix",
            solo_served,
            shard_tput(shard_runs[0].1.responses.len(), &shard_runs[0].2),
            shard_tput(shard_runs[1].1.responses.len(), &shard_runs[1].2),
            shard_tput(shard_runs[2].1.responses.len(), &shard_runs[2].2),
            steal_out.responses.len(),
            static_out.responses.len()
        );
        println!(
            "check ✓ pressure-driven served {} >= fixed-slot {}, compressed concurrency {} > uncompressed {}, batched fetch served {} >= per-seq {} in {} vs {} dispatches",
            co.responses.len(),
            fx.responses.len(),
            co.peak_active,
            un.peak_active,
            co.responses.len(),
            ps.responses.len(),
            cm.fetch_dispatches,
            psm.fetch_dispatches
        );
    }
}
