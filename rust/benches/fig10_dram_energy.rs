//! Fig 10: DRAM read+activation energy per weight, proposed bit-plane (P)
//! vs traditional byte-level (T), for 12 (model, base) configs under the
//! Fig 9 precision distributions, on the DDR5-4800 4-channel simulator.
//!
//! Traffic is simulated at a sampled scale (64 MB of weight reads per
//! config) and energy reported per weight — energy is linear in traffic,
//! so sampling preserves ratios exactly (DESIGN.md substitutions).
//!
//!     cargo bench --bench fig10_dram_energy

use camc::compress::Codec;
use camc::configs::ddr5::DDR5_4800_PAPER;
use camc::configs::SWEEP_MODELS;
use camc::dram::MemorySystem;
use camc::fmt::Dtype;
use camc::quant::mode::RouterSim;
use camc::quant::traffic::WeightTraffic;
use camc::report::Table;
use camc::synth::{encode_checkpoint, sample_checkpoint};

const SAMPLE_WEIGHTS: u64 = 32_000_000; // weights simulated per config

fn energy_per_weight(bits_per_weight: f64) -> (f64, f64) {
    // stream the equivalent bytes through the DRAM sim, report
    // (pJ/weight, utilized-BW fraction)
    let bytes = (SAMPLE_WEIGHTS as f64 * bits_per_weight / 8.0) as u64;
    let mut mem = MemorySystem::new(DDR5_4800_PAPER.clone());
    let cycles = mem.run_stream_read(0, bytes);
    let e = mem.stats.energy_pj(&mem.cfg);
    let pj = (e.read_pj + e.activation_pj) / SAMPLE_WEIGHTS as f64;
    let bw = bytes as f64 / (cycles as f64 * mem.cfg.t_ck())
        / (mem.cfg.peak_bw_per_channel() * mem.cfg.channels as f64);
    (pj, bw)
}

fn main() {
    let mut tab = Table::new(
        "Fig 10 — DRAM read+activation energy per weight (DDR5-4800 4ch)",
        &["model", "base", "P pJ/w", "T pJ/w", "savings"],
    );
    for cfg in SWEEP_MODELS {
        for base in [Dtype::Bf16, Dtype::Fp8E4M3, Dtype::Int4] {
            let ts = sample_checkpoint(cfg, 1 << 17, 42);
            let t = encode_checkpoint(&ts, base);
            let tr = WeightTraffic::measure(base, &t.codes, Codec::Zstd);
            let dist = RouterSim::paper_default(cfg.name).simulate(base, 1200, 64, 7);
            let (pb, tb) = tr.avg_bits(&dist);
            let (p_pj, _) = energy_per_weight(pb);
            let (t_pj, _) = energy_per_weight(tb);
            tab.row(&[
                cfg.name.into(),
                base.to_string(),
                format!("{p_pj:.1}"),
                format!("{t_pj:.1}"),
                format!("{:.1}%", (1.0 - p_pj / t_pj) * 100.0),
            ]);
        }
    }
    tab.print();
    println!(
        "paper: BF16 savings 25.9-29.9%, shrinking with base precision\n\
         (FP8 ~19.6%, INT4 ~17.9% on Mixtral). shape: savings(BF16) >\n\
         savings(FP8) > savings(INT4) > 0 per model."
    );
}
