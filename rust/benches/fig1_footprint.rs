//! Fig 1: KV cache vs model weights share of total memory footprint as
//! sequence length grows (LLaMA 3.1 8B).
//!
//!     cargo bench --bench fig1_footprint

use camc::configs::LLAMA31_8B;
use camc::coordinator::footprint_curve;
use camc::report::Table;
use camc::util::humanfmt;

fn main() {
    for batch in [1u64, 32] {
        let pts = footprint_curve(
            &LLAMA31_8B,
            16,
            batch,
            &[128, 512, 2048, 8192, 16384, 32768, 65536, 131072],
        );
        let mut tab = Table::new(
            &format!("Fig 1 — LLaMA 3.1 8B footprint split (batch {batch})"),
            &["seq len", "weights", "KV cache", "KV share"],
        );
        for p in &pts {
            tab.row(&[
                p.seq_len.to_string(),
                humanfmt::bytes(p.weight_bytes),
                humanfmt::bytes(p.kv_bytes),
                format!("{:.1}%", p.kv_fraction() * 100.0),
            ]);
        }
        tab.print();
    }
    println!("paper shape: KV share exceeds 90% beyond a few thousand tokens (batched).");
}
