//! Table I: footprint reduction under naive (value-major) LZ4/ZSTD for
//! model weights and KV caches of five models.
//!
//!     cargo bench --bench table1_baseline_compression

use camc::bitplane::value_major_ratio;
use camc::compress::Codec;
use camc::configs::TABLE1_MODELS;
use camc::fmt::Dtype;
use camc::report::Table;
use camc::synth::{encode_checkpoint, gen_kv_layer, sample_checkpoint, CorpusProfile};

fn main() {
    let savings = |r: f64| format!("{:.1}%", (1.0 - 1.0 / r).max(0.0) * 100.0);

    let mut wt = Table::new(
        "Table I (weights): naive-layout footprint reduction, 4 KB blocks",
        &["codec", "LLaMA 3.1 8B", "Gemma 2 2B", "Mistral 7B", "OPT 13B", "Mixtral 8x7B"],
    );
    let mut weight_rows: Vec<Vec<String>> = vec![vec!["LZ4".into()], vec!["ZSTD".into()]];
    for cfg in TABLE1_MODELS {
        let ts = sample_checkpoint(cfg, 1 << 18, 42);
        let t = encode_checkpoint(&ts, Dtype::Bf16);
        for (i, codec) in [Codec::Lz4, Codec::Zstd].iter().enumerate() {
            let r = value_major_ratio(Dtype::Bf16, &t.codes, *codec, 4096);
            weight_rows[i].push(savings(r));
        }
    }
    for r in weight_rows {
        wt.rowv(r);
    }
    wt.print();

    let mut kt = Table::new(
        "Table I (KV cache, book-profile): naive-layout footprint reduction",
        &["codec", "LLaMA 3.1 8B", "Gemma 2 2B", "Mistral 7B", "OPT 13B", "Mixtral 8x7B"],
    );
    let mut kv_rows: Vec<Vec<String>> = vec![vec!["LZ4".into()], vec!["ZSTD".into()]];
    for cfg in TABLE1_MODELS {
        let ch = (cfg.n_kv_heads * cfg.d_head()).min(512);
        let kv = gen_kv_layer(256, ch, CorpusProfile::Book, 0.5, 7);
        for (i, codec) in [Codec::Lz4, Codec::Zstd].iter().enumerate() {
            let r = value_major_ratio(Dtype::Bf16, &kv, *codec, 4096);
            kv_rows[i].push(savings(r));
        }
    }
    for r in kv_rows {
        kt.rowv(r);
    }
    kt.print();
    println!(
        "paper: weights LZ4 0-18%, ZSTD 17.3-23.0%; KV LZ4 0%, ZSTD 0.9-6.5%.\n\
         shape to hold: LZ4 ~ 0 everywhere; ZSTD weights >> ZSTD KV."
    );
}
