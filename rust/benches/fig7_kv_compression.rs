//! Fig 7: per-layer KV-cache compression ratios, proposed (cluster +
//! expdelta + bit-plane) vs baseline (bit-plane only), LZ4 + ZSTD, on
//! both corpus profiles — measured on the REAL tinylm KV caches when
//! artifacts exist, plus the synthetic 32-layer LLaMA-8B analog.
//!
//!     cargo bench --bench fig7_kv_compression

use camc::bitplane::plane_major_ratio;
use camc::compress::Codec;
use camc::fmt::minifloat::BF16;
use camc::fmt::Dtype;
use camc::kvcluster::{cluster_ratio, DecorrelateMode};
use camc::report::Table;
use camc::runtime::model::KvState;
use camc::runtime::{read_u16_stream, TinyLm};
use camc::synth::{gen_kv_layer, CorpusProfile};

fn main() {
    // ---- synthetic 32-layer LLaMA 3.1 8B analog ----
    for profile in [CorpusProfile::Wiki, CorpusProfile::Book] {
        let mut tab = Table::new(
            &format!("Fig 7 analog — synthetic LLaMA-8B KV, {}", profile.name()),
            &["layer", "base LZ4", "base ZSTD", "ours LZ4", "ours ZSTD"],
        );
        let (tok, ch) = (256usize, 1024usize);
        let mut totals = [0.0f64; 4];
        let layers = 8; // sampled layers of 32 (ratio varies smoothly)
        for l in 0..layers {
            let frac = l as f64 / (layers - 1) as f64;
            let kv = gen_kv_layer(tok, ch, profile, frac, 100 + l as u64);
            let base_l = plane_major_ratio(Dtype::Bf16, &kv, Codec::Lz4, 4096);
            let base_z = plane_major_ratio(Dtype::Bf16, &kv, Codec::Zstd, 4096);
            let ours_l = cluster_ratio(
                Dtype::Bf16,
                tok,
                ch,
                &kv,
                16,
                DecorrelateMode::ExpDelta,
                Codec::Lz4,
            );
            let ours_z = cluster_ratio(
                Dtype::Bf16,
                tok,
                ch,
                &kv,
                16,
                DecorrelateMode::ExpDelta,
                Codec::Zstd,
            );
            for (t, v) in totals.iter_mut().zip([base_l, base_z, ours_l, ours_z]) {
                *t += v / layers as f64;
            }
            tab.row(&[
                format!("{}", l * 4),
                format!("{base_l:.2}"),
                format!("{base_z:.2}"),
                format!("{ours_l:.2}"),
                format!("{ours_z:.2}"),
            ]);
        }
        tab.row(&[
            "MEAN".into(),
            format!("{:.2}", totals[0]),
            format!("{:.2}", totals[1]),
            format!("{:.2}", totals[2]),
            format!("{:.2}", totals[3]),
        ]);
        tab.print();
    }

    // ---- real tinylm KV caches (if artifacts are built) ----
    if std::path::Path::new("artifacts/weights.camt").exists() {
        let lm = TinyLm::load("artifacts").expect("tinylm");
        let mut tab = Table::new(
            "Fig 7 (real tinylm KV via PJRT decode)",
            &["corpus", "layer", "baseline ZSTD", "ours ZSTD", "gain"],
        );
        for corpus in ["wiki", "book"] {
            let toks =
                read_u16_stream(std::path::Path::new(&format!("artifacts/corpus_{corpus}.bin")))
                    .unwrap();
            let mut kv = KvState::new(&lm.meta);
            let mask = vec![0.0f32; lm.meta.n_pages];
            for &t in toks.iter().take(lm.meta.max_seq) {
                lm.decode_step(&mut kv, t, &mask).unwrap();
            }
            let row = lm.meta.n_kv_heads * lm.meta.d_head;
            for l in 0..lm.meta.layers {
                let mut codes = Vec::new();
                for t in 0..lm.meta.max_seq {
                    let off = (l * lm.meta.max_seq + t) * row;
                    codes.extend(kv.k[off..off + row].iter().map(|&x| BF16.encode(x) as u16));
                }
                let base = plane_major_ratio(Dtype::Bf16, &codes, Codec::Zstd, 4096);
                let ours = cluster_ratio(
                    Dtype::Bf16,
                    lm.meta.max_seq,
                    row,
                    &codes,
                    16,
                    DecorrelateMode::ExpDelta,
                    Codec::Zstd,
                );
                tab.row(&[
                    corpus.into(),
                    l.to_string(),
                    format!("{base:.2}"),
                    format!("{ours:.2}"),
                    format!("{:+.1}%", (ours / base - 1.0) * 100.0),
                ]);
            }
        }
        tab.print();
    }
    println!(
        "paper: overall ratios — baseline ZSTD 1.21 (wiki) / 1.33 (book);\n\
         ours 1.81 (wiki) / 1.88 (book); improvement 50.3% / 41.7%.\n\
         shape: ours > baseline on every layer, larger gains where channel\n\
         coherence is higher."
    );
}
