//! Fig 8: per-bit-plane compressibility (ZSTD, 4 KB blocks) for BF16 /
//! FP8 / INT4 weights and BF16 KV caches on both corpora.
//!
//!     cargo bench --bench fig8_bitplane_compressibility

use camc::bitplane::per_plane_ratios;
use camc::compress::entropy::bit_entropy;
use camc::compress::Codec;
use camc::configs::LLAMA31_8B;
use camc::fmt::Dtype;
use camc::kvcluster::{decorrelate, DecorrelateMode, KvGroup};
use camc::report::Table;
use camc::synth::{encode_checkpoint, gen_kv_layer, sample_checkpoint, CorpusProfile};

fn plane_table(title: &str, dtype: Dtype, codes: &[u16]) {
    let ratios = per_plane_ratios(dtype, codes, Codec::Zstd, 4096);
    let pb = camc::bitplane::disaggregate(dtype, codes);
    let mut tab = Table::new(title, &["plane (msb=0)", "field", "bit H", "zstd ratio"]);
    let (elo, ehi) = dtype.exponent_planes();
    let n = dtype.bits();
    for (p, r) in ratios.iter().enumerate() {
        let bit = n - 1 - p as u32;
        let field = if bit == n - 1 {
            "sign"
        } else if bit >= elo && bit < ehi {
            "exponent"
        } else {
            "mantissa"
        };
        tab.row(&[
            p.to_string(),
            field.into(),
            format!("{:.3}", bit_entropy(pb.plane(p))),
            format!("{r:.2}"),
        ]);
    }
    tab.print();
}

fn main() {
    let ts = sample_checkpoint(&LLAMA31_8B, 1 << 18, 42);
    for dtype in [Dtype::Bf16, Dtype::Fp8E4M3, Dtype::Int4] {
        let t = encode_checkpoint(&ts, dtype);
        plane_table(
            &format!("Fig 8 — LLaMA-8B weights @ {dtype}, per-plane ZSTD"),
            dtype,
            &t.codes,
        );
    }
    for profile in [CorpusProfile::Wiki, CorpusProfile::Book] {
        let (tok, ch) = (256usize, 1024usize);
        let kv = gen_kv_layer(tok, ch, profile, 0.5, 5);
        // the paper's KV planes are measured after cluster + delta
        let g = KvGroup::new(Dtype::Bf16, tok, ch, kv);
        let cm = g.channel_major();
        let (tr, _) = decorrelate(Dtype::Bf16, tok, ch, &cm, DecorrelateMode::ExpDelta);
        plane_table(
            &format!("Fig 8 — KV cache (clustered+delta) @ bf16, {}", profile.name()),
            Dtype::Bf16,
            &tr,
        );
    }
    println!(
        "paper shape: exponent planes dominate compressibility for BF16;\n\
         FP8/INT4 planes are near-incompressible; KV exponent planes\n\
         compress even harder than weights'."
    );
}
