//! Fig 9: precision distribution of weight traffic under MoDE dynamic
//! quantization for 12 (model, base-precision) configs — plus the Fig 3
//! analog (prune-only vs dynamic quantization quality proxy).
//!
//!     cargo bench --bench fig9_precision_distribution

use camc::configs::SWEEP_MODELS;
use camc::fmt::Dtype;
use camc::quant::mode::{precision_menu, RouterSim};
use camc::report::Table;

fn main() {
    for base in [Dtype::Bf16, Dtype::Fp8E4M3, Dtype::Int4] {
        let menu = precision_menu(base);
        let mut headers: Vec<String> = vec!["model".into()];
        headers.extend(menu.iter().map(|d| d.to_string()));
        headers.push("avg bits".into());
        let hdr_refs: Vec<&str> = headers.iter().map(String::as_str).collect();
        let mut tab = Table::new(
            &format!("Fig 9 — precision distribution, {base}-based models"),
            &hdr_refs,
        );
        for cfg in SWEEP_MODELS {
            let r = RouterSim::paper_default(cfg.name);
            let d = r.simulate(base, 2000, 64, 7);
            let mut row = vec![cfg.name.to_string()];
            row.extend(d.fractions.iter().map(|f| format!("{:.1}%", f * 100.0)));
            row.push(format!("{:.2}", d.avg_bits()));
            tab.rowv(row);
        }
        tab.print();
    }

    // Fig 3 analog: prune-only vs dynamic quantization. Quality proxy =
    // effective information retained per component (1 for full precision,
    // 0 for skipped, fraction of significant bits otherwise), which tracks
    // the zero-shot accuracy ordering the paper reports.
    let mut tab = Table::new(
        "Fig 3 analog — routing budget spent as prune vs dynamic quant",
        &["scheme", "kept info/component", "avg bits"],
    );
    let r = RouterSim::paper_default("LLaMA-MoE-3.5B");
    let d = r.simulate(Dtype::Bf16, 2000, 64, 11);
    // (a) prune-only: same traffic budget achieved by dropping components
    let avg_bits = d.avg_bits();
    let prune_keep = avg_bits / 16.0; // fraction of components kept at bf16
    let prune_info = prune_keep * 1.0;
    // (b)/(c) dynamic quant: info per component grows ~log with bits
    let dq_info: f64 = d
        .levels
        .iter()
        .zip(&d.fractions)
        .map(|(l, f)| f * (l.bits() as f64 / 16.0).powf(0.5))
        .sum();
    tab.row(&[
        "prune-only (a)".into(),
        format!("{prune_info:.3}"),
        format!("{avg_bits:.2}"),
    ]);
    tab.row(&[
        "dynamic quant (b/c)".into(),
        format!("{dq_info:.3}"),
        format!("{avg_bits:.2}"),
    ]);
    tab.print();
    println!(
        "paper shape: at matched traffic, quantizing more components to lower\n\
         precision beats skipping them (Fig 3: +1.9pp PIQA) — here the kept-\n\
         information proxy is higher for dynamic quant at equal avg bits."
    );
}
