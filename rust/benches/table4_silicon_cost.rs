//! Table IV: silicon cost of the compression subsystem at 2 GHz x 32
//! lanes, LZ4 + ZSTD engines, three block sizes — from the ASAP7-
//! calibrated component model (validated against all six published
//! points in unit tests).
//!
//!     cargo bench --bench table4_silicon_cost

use camc::compress::Codec;
use camc::hwmodel::{SiliconModel, TABLE4_POINTS};
use camc::report::Table;

fn main() {
    let m = SiliconModel::calibrated();
    let mut tab = Table::new(
        "Table IV — silicon cost @ 2 GHz, 32 lanes (ASAP7 7 nm)",
        &[
            "engine",
            "block bits",
            "SL area mm2",
            "SL power mW",
            "tot area mm2",
            "tot power mW",
            "SL Gbps",
        ],
    );
    for codec in [Codec::Lz4, Codec::Zstd] {
        for bits in [16384u64, 32768, 65536] {
            tab.row(&[
                codec.to_string().to_uppercase(),
                bits.to_string(),
                format!("{:.5}", m.sl_area_mm2(codec, bits)),
                format!("{:.3}", m.sl_power_mw(codec, bits)),
                format!("{:.5}", m.total_area_mm2(codec, bits, 32)),
                format!("{:.3}", m.total_power_mw(codec, bits, 32)),
                "512".into(),
            ]);
        }
    }
    tab.print();

    // deltas vs the published table
    let mut dev = 0.0f64;
    for p in TABLE4_POINTS {
        dev = dev.max((m.sl_area_mm2(p.engine, p.block_bits) - p.sl_area_mm2).abs());
        dev = dev
            .max(((m.sl_power_mw(p.engine, p.block_bits) - p.sl_power_mw) / p.sl_power_mw).abs());
    }
    println!("max deviation from the paper's six published points: {dev:.2e}");
    println!("aggregate throughput: {} Gbps = 2 TB/s", m.total_gbps(32));
}
