//! Hot-path microbenchmarks (the §Perf harness): bit-plane shuffle,
//! LZ4/zstd-class compress+decompress, DRAM-sim command rate, KV cluster
//! pipeline. Prints throughput per path; EXPERIMENTS.md §Perf records the
//! before/after across optimization iterations.
//!
//!     cargo bench --bench hotpath_microbench

use std::time::Instant;

use camc::bitplane::layout::{disaggregate, reaggregate};
use camc::compress::Codec;
use camc::configs::ddr5::DDR5_4800_PAPER;
use camc::dram::MemorySystem;
use camc::fmt::minifloat::BF16;
use camc::fmt::Dtype;
use camc::kvcluster::{ClusteredBlock, DecorrelateMode, KvGroup};
use camc::report::Table;
use camc::synth::{gen_kv_layer, CorpusProfile};
use camc::util::humanfmt;
use camc::util::rng::Xoshiro256;

fn time<F: FnMut()>(mut f: F, iters: usize) -> f64 {
    // warmup
    f();
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    t0.elapsed().as_secs_f64() / iters as f64
}

fn main() {
    let mut tab = Table::new("hot paths", &["path", "unit", "time", "throughput"]);
    let mut r = Xoshiro256::new(1);

    // weight-like bf16 codes, 1 MiB
    let n = 512 * 1024;
    let codes: Vec<u16> = (0..n)
        .map(|_| BF16.encode((r.normal() * 0.02) as f32) as u16)
        .collect();
    let bytes = (n * 2) as f64;

    let dis = time(|| { std::hint::black_box(disaggregate(Dtype::Bf16, &codes)); }, 8);
    tab.row(&[
        "bitplane disaggregate".into(),
        humanfmt::bytes(bytes as u64),
        humanfmt::nanos(dis * 1e9),
        humanfmt::rate(bytes / dis),
    ]);

    let pb = disaggregate(Dtype::Bf16, &codes);
    let rea = time(|| { std::hint::black_box(reaggregate(Dtype::Bf16, n, &pb.planes)); }, 8);
    tab.row(&[
        "bitplane reaggregate".into(),
        humanfmt::bytes(bytes as u64),
        humanfmt::nanos(rea * 1e9),
        humanfmt::rate(bytes / rea),
    ]);

    // compressors over the concatenated planes (the real input shape)
    let plane_stream: Vec<u8> = pb.planes.concat();
    for codec in [Codec::Lz4, Codec::Zstd] {
        let c = time(|| { std::hint::black_box(codec.compress(&plane_stream)); }, 4);
        tab.row(&[
            format!("{codec} compress (planes)"),
            humanfmt::bytes(plane_stream.len() as u64),
            humanfmt::nanos(c * 1e9),
            humanfmt::rate(plane_stream.len() as f64 / c),
        ]);
        let comp = codec.compress(&plane_stream);
        let d = time(
            || { std::hint::black_box(codec.decompress(&comp, plane_stream.len()).unwrap()); },
            4,
        );
        tab.row(&[
            format!("{codec} decompress"),
            humanfmt::bytes(plane_stream.len() as u64),
            humanfmt::nanos(d * 1e9),
            humanfmt::rate(plane_stream.len() as f64 / d),
        ]);
    }

    // KV cluster pipeline (compress one 16-token x 1024-ch group)
    let kv_codes = gen_kv_layer(16, 1024, CorpusProfile::Book, 0.5, 3);
    let kv = KvGroup::new(Dtype::Bf16, 16, 1024, kv_codes);
    let kc = time(
        || { std::hint::black_box(ClusteredBlock::compress(&kv, DecorrelateMode::ExpDelta, Codec::Zstd)); },
        16,
    );
    let kv_bytes = (16 * 1024 * 2) as f64;
    tab.row(&[
        "kv cluster+delta+zstd".into(),
        humanfmt::bytes(kv_bytes as u64),
        humanfmt::nanos(kc * 1e9),
        humanfmt::rate(kv_bytes / kc),
    ]);

    // DRAM sim command rate
    let mut mem = MemorySystem::new(DDR5_4800_PAPER.clone());
    let t0 = Instant::now();
    let sim_bytes = 32u64 << 20;
    let cycles = mem.run_stream_read(0, sim_bytes);
    let wall = t0.elapsed().as_secs_f64();
    tab.row(&[
        "dram sim (streaming)".into(),
        format!("{cycles} cyc"),
        humanfmt::nanos(wall * 1e9),
        format!("{:.1} Mcyc/s", cycles as f64 / wall / 1e6),
    ]);

    tab.print();
}
