//! Hot-path microbenchmarks (the §Perf harness): bit-plane shuffle,
//! LZ4/zstd-class compress+decompress (one-shot vs reusable-scratch lane
//! entry points), KV transpose (naive vs blocked), the multi-lane engine's
//! batched-compress scaling sweep, small-batch dispatch (pooled vs the
//! spawn/join reference vs serial), a serve()-shaped end-to-end step loop,
//! DRAM-sim command rate, KV cluster pipeline. Prints throughput per path
//! AND writes a machine-readable `BENCH_hotpath.json` (path → bytes/s) so
//! future PRs can track the perf trajectory.
//!
//!     cargo bench --bench hotpath_microbench [-- --fast] [-- --check]
//!
//! `--fast` trims iteration counts/sizes for CI smoke runs; `--check`
//! exits non-zero if the pooled small-batch dispatch is slower than the
//! serial path, if the batched/arena decode fetch is slower than the
//! per-sequence or per-page-Vec shapes, or if the lazy view plan is
//! slower than the materializing copy plan (the regressions CI gates
//! on).

use std::sync::Arc;
use std::time::Instant;

use camc::bitplane::layout::{disaggregate, reaggregate_flat};
use camc::compress::{Codec, CodecScratch};
use camc::configs::ddr5::DDR5_4800_PAPER;
use camc::dram::{MemorySystem, ShardedMemSystem};
use camc::engine::{Lane, LaneArray};
use camc::fmt::minifloat::BF16;
use camc::fmt::Dtype;
use camc::kvcluster::{ClusteredBlock, DecorrelateMode, KvGroup};
use camc::report::{BenchReport, Table};
use camc::synth::{gen_kv_layer, CorpusProfile};
use camc::util::humanfmt;
use camc::util::rng::Xoshiro256;

fn time<F: FnMut()>(mut f: F, iters: usize) -> f64 {
    // warmup
    f();
    let t0 = Instant::now();
    for _ in 0..iters {
        f();
    }
    t0.elapsed().as_secs_f64() / iters as f64
}

struct Bench {
    tab: Table,
    report: BenchReport,
}

impl Bench {
    fn new() -> Self {
        Self {
            tab: Table::new("hot paths", &["path", "unit", "time", "throughput"]),
            report: BenchReport::new(),
        }
    }

    /// One benchmark row: table line + JSON entry (bytes/s).
    fn row(&mut self, path: &str, unit: String, secs: f64, bytes: f64) {
        self.tab.row(&[
            path.into(),
            unit,
            humanfmt::nanos(secs * 1e9),
            humanfmt::rate(bytes / secs),
        ]);
        self.report.insert(path, (bytes / secs).round());
    }
}

fn main() {
    let args: Vec<String> = std::env::args().collect();
    let fast = args.iter().any(|a| a == "--fast");
    let check = args.iter().any(|a| a == "--check");
    let mut b = Bench::new();
    let mut r = Xoshiro256::new(1);

    // ---- bit-plane shuffle (1 MiB of weight-like bf16 codes) ----
    let n = 512 * 1024;
    let codes: Vec<u16> = (0..n)
        .map(|_| BF16.encode((r.normal() * 0.02) as f32) as u16)
        .collect();
    let bytes = (n * 2) as f64;

    let dis = time(|| { std::hint::black_box(disaggregate(Dtype::Bf16, &codes)); }, 8);
    b.row("bitplane disaggregate", humanfmt::bytes(bytes as u64), dis, bytes);

    let pb = disaggregate(Dtype::Bf16, &codes);
    let rea = time(
        || { std::hint::black_box(reaggregate_flat(Dtype::Bf16, n, pb.all_bytes(), 16)); },
        8,
    );
    b.row("bitplane reaggregate", humanfmt::bytes(bytes as u64), rea, bytes);

    // ---- codecs over the concatenated planes (the real input shape) ----
    let heavy = if fast { 2 } else { 4 };
    let plane_stream: Vec<u8> = pb.all_bytes().to_vec();
    for codec in [Codec::Lz4, Codec::Zstd] {
        let c = time(|| { std::hint::black_box(codec.compress(&plane_stream)); }, heavy);
        b.row(
            &format!("{codec} compress (planes)"),
            humanfmt::bytes(plane_stream.len() as u64),
            c,
            plane_stream.len() as f64,
        );
        let comp = codec.compress(&plane_stream);
        let d = time(
            || { std::hint::black_box(codec.decompress(&comp, plane_stream.len()).unwrap()); },
            heavy,
        );
        b.row(
            &format!("{codec} decompress"),
            humanfmt::bytes(plane_stream.len() as u64),
            d,
            plane_stream.len() as f64,
        );
    }

    // ---- single block, seed-style one-shot vs lane scratch path ----
    // One 4 KB-logical block (2048 bf16 codes): the seed compressed each
    // plane with a fresh hash table + output Vec; a lane reuses both.
    let block_codes: Vec<u16> = codes[..2048].to_vec();
    let block_pb = disaggregate(Dtype::Bf16, &block_codes);
    let block_bytes = (block_codes.len() * 2) as f64;
    for codec in [Codec::Lz4, Codec::Zstd] {
        let before = time(
            || {
                for p in block_pb.planes() {
                    std::hint::black_box(codec.compress(p));
                }
            },
            64,
        );
        b.row(
            &format!("block compress one-shot ({codec})"),
            humanfmt::bytes(block_bytes as u64),
            before,
            block_bytes,
        );
        let mut lane = Lane::new(0);
        let mut payload = Vec::new();
        let after = time(
            || {
                payload.clear();
                std::hint::black_box(lane.compress_planes(&block_pb, codec, &mut payload));
            },
            64,
        );
        b.row(
            &format!("block compress lane-scratch ({codec})"),
            humanfmt::bytes(block_bytes as u64),
            after,
            block_bytes,
        );
    }
    // scratch decompress of one block
    {
        let mut scratch = CodecScratch::new();
        let mut comp = Vec::new();
        Codec::Zstd.compress_into(&plane_stream, &mut scratch, &mut comp);
        let mut out = Vec::new();
        let d = time(
            || {
                out.clear();
                Codec::Zstd
                    .decompress_append(&comp, plane_stream.len(), &mut out)
                    .unwrap();
                std::hint::black_box(&out);
            },
            heavy,
        );
        b.row(
            "zstd decompress append (reused buf)",
            humanfmt::bytes(plane_stream.len() as u64),
            d,
            plane_stream.len() as f64,
        );
    }

    // ---- KV transpose: naive scatter vs blocked tiles ----
    let (tok, ch) = (512, 1024);
    let kv_big = gen_kv_layer(tok, ch, CorpusProfile::Book, 0.5, 5);
    let kv_bytes_big = (tok * ch * 2) as f64;
    let naive = time(
        || {
            let mut out = vec![0u16; kv_big.len()];
            for t in 0..tok {
                for j in 0..ch {
                    out[j * tok + t] = kv_big[t * ch + j];
                }
            }
            std::hint::black_box(out);
        },
        16,
    );
    b.row(
        "kv transpose naive (512x1024)",
        humanfmt::bytes(kv_bytes_big as u64),
        naive,
        kv_bytes_big,
    );
    let kvg_big = KvGroup::new(Dtype::Bf16, tok, ch, kv_big.clone());
    let blocked = time(|| { std::hint::black_box(kvg_big.channel_major()); }, 16);
    b.row(
        "kv transpose blocked (512x1024)",
        humanfmt::bytes(kv_bytes_big as u64),
        blocked,
        kv_bytes_big,
    );

    // ---- KV cluster pipeline (compress one 16-token x 1024-ch group) ----
    let kv_codes = gen_kv_layer(16, 1024, CorpusProfile::Book, 0.5, 3);
    let kv = KvGroup::new(Dtype::Bf16, 16, 1024, kv_codes);
    let kc = time(
        || {
            std::hint::black_box(ClusteredBlock::compress(
                &kv,
                DecorrelateMode::ExpDelta,
                Codec::Zstd,
            ));
        },
        16,
    );
    let kv_bytes = (16 * 1024 * 2) as f64;
    b.row("kv cluster+delta+zstd", humanfmt::bytes(kv_bytes as u64), kc, kv_bytes);

    // ---- batched compress path: serial seed-style vs lane sweep ----
    // 64 weight blocks of 2048 bf16 codes (4 KB logical each) — the
    // store_weights inner loop. The serial baseline reproduces the seed's
    // allocation-heavy path (fresh tables + fresh Vec per plane).
    let nblocks = 64usize;
    let blocks: Vec<Vec<u16>> = (0..nblocks)
        .map(|i| codes[i * 2048..(i + 1) * 2048].to_vec())
        .collect();
    let batch_bytes = (nblocks * 2048 * 2) as f64;
    let codec = Codec::Zstd;
    let serial_seed = time(
        || {
            for bc in &blocks {
                let pb = disaggregate(Dtype::Bf16, bc);
                for p in pb.planes() {
                    let c = codec.compress(p);
                    std::hint::black_box(if c.len() < p.len() { c } else { p.to_vec() });
                }
            }
        },
        3,
    );
    b.row(
        "batch compress serial seed-style",
        humanfmt::bytes(batch_bytes as u64),
        serial_seed,
        batch_bytes,
    );
    let mut lane_rates: Vec<(usize, f64)> = Vec::new();
    for lanes in [1usize, 2, 4, 8, 16, 32] {
        let la = LaneArray::new(lanes);
        let t = time(
            || {
                let out = la.run(&blocks, |lane, bc| {
                    let pb = disaggregate(Dtype::Bf16, bc);
                    let mut payload = Vec::new();
                    let dir = lane.compress_planes(&pb, codec, &mut payload);
                    (dir, payload)
                });
                std::hint::black_box(out);
            },
            3,
        );
        b.row(
            &format!("batch compress {lanes} lane(s)"),
            humanfmt::bytes(batch_bytes as u64),
            t,
            batch_bytes,
        );
        lane_rates.push((lanes, batch_bytes / t));
    }
    // decode sweep over the same blocks
    let stored: Vec<(Vec<(u32, bool)>, Vec<u8>)> = {
        let la = LaneArray::new(1);
        la.run(&blocks, |lane, bc| {
            let pb = disaggregate(Dtype::Bf16, bc);
            let mut payload = Vec::new();
            let dir = lane.compress_planes(&pb, codec, &mut payload);
            (dir, payload)
        })
    };
    for lanes in [1usize, 8, 32] {
        let la = LaneArray::new(lanes);
        let t = time(
            || {
                let out = la.run(&stored, |lane, (dir, payload)| {
                    lane.decode_planes(Dtype::Bf16, 2048, codec, dir, payload, 16)
                        .unwrap()
                });
                std::hint::black_box(out);
            },
            3,
        );
        b.row(
            &format!("batch decompress {lanes} lane(s)"),
            humanfmt::bytes(batch_bytes as u64),
            t,
            batch_bytes,
        );
    }

    // ---- small-batch dispatch: pooled vs spawn/join vs serial ----
    // Per-decode-step batches are a few blocks. The persistent pool must
    // beat per-batch thread spawn/join there — and must not lose to the
    // serial path — for serve() to benefit (CI gates on the latter via
    // --check).
    // (nb, serial, pooled, spawn/join)
    let mut small_rows: Vec<(usize, f64, f64, f64)> = Vec::new();
    let mut pooled_ok = true;
    {
        let la8 = LaneArray::new(8);
        let la1 = LaneArray::new(1);
        let iters = if fast { 40 } else { 160 };
        let work = |lane: &mut Lane, bc: &Vec<u16>| {
            let pb = disaggregate(Dtype::Bf16, bc);
            let mut payload = Vec::new();
            let dir = lane.compress_planes(&pb, Codec::Zstd, &mut payload);
            (dir, payload)
        };
        // informational rows: fixed 8-lane pool for stable JSON keys
        // across hosts (the perf-trajectory artifact)
        for &nb in &[1usize, 4, 8] {
            let small: Vec<Vec<u16>> = blocks[..nb].to_vec();
            let small_bytes = (nb * 2048 * 2) as f64;
            let tser = time(|| { std::hint::black_box(la1.run(&small, work)); }, iters);
            b.row(
                &format!("small batch {nb} blk serial"),
                humanfmt::bytes(small_bytes as u64),
                tser,
                small_bytes,
            );
            let tpool = time(|| { std::hint::black_box(la8.run(&small, work)); }, iters);
            b.row(
                &format!("small batch {nb} blk pooled (8 lanes)"),
                humanfmt::bytes(small_bytes as u64),
                tpool,
                small_bytes,
            );
            let tsj = time(|| { std::hint::black_box(la8.run_spawn_join(&small, work)); }, iters);
            b.row(
                &format!("small batch {nb} blk spawn-join (8 lanes)"),
                humanfmt::bytes(small_bytes as u64),
                tsj,
                small_bytes,
            );
            small_rows.push((nb, small_bytes / tser, small_bytes / tpool, small_bytes / tsj));
        }
        // regression gate (--check): measured on the host-capped pool —
        // the configuration serve()/default_pool actually run, so a
        // 2-core CI runner is not forced to oversubscribe 8 lanes. The
        // 10% tolerance absorbs timer noise and a failing size is
        // re-measured up to twice; only consistently-slower-than-serial
        // dispatch (a real pool regression) fails all three attempts.
        // 1-block batches are skipped: they take the inline path on both
        // sides by construction.
        if check {
            let la_host = LaneArray::with_default_lanes();
            for &nb in &[4usize, 8] {
                let small: Vec<Vec<u16>> = blocks[..nb].to_vec();
                let measure = || {
                    let tser = time(|| { std::hint::black_box(la1.run(&small, work)); }, iters);
                    let tpool =
                        time(|| { std::hint::black_box(la_host.run(&small, work)); }, iters);
                    tser / tpool
                };
                let mut ratio = measure();
                for _ in 0..2 {
                    if ratio >= 0.90 {
                        break;
                    }
                    ratio = ratio.max(measure());
                }
                if ratio < 0.90 {
                    eprintln!(
                        "gate: {nb}-blk pooled ({} lanes) {ratio:.2}x serial after retries",
                        la_host.lane_count()
                    );
                    pooled_ok = false;
                }
            }
        }
    }

    // ---- serve()-shaped end-to-end step loop ----
    // 8 sequences, continuous decode: per-step policy degrade sweeps plus
    // page sync, all through ONE shared lane pool — batched cross-sequence
    // sync vs the per-sequence path the old serve loop used.
    let mut fetch_ok = true;
    let mut plan_ok = true;
    {
        use camc::coordinator::{
            fetch_sequences, sync_sequences, DecodeArena, KvPageStore, KvViewPlan, PolicyEngine,
        };
        use camc::memctrl::Layout;
        use camc::quant::policy::{KvPolicy, PageTier};
        use camc::runtime::model::{KvState, ModelMeta};

        let meta = ModelMeta {
            vocab: 256,
            layers: 4,
            d_model: 64,
            n_heads: 8,
            n_kv_heads: 4,
            d_head: 16,
            max_seq: 256,
            kv_channels: 64,
            prefill_len: 64,
            page_tokens: 16,
            n_pages: 16,
            param_names: vec![],
        };
        let nseq = 8usize;
        let prefill = 64usize;
        let steps = if fast { 32 } else { 128 };
        let row = meta.n_kv_heads * meta.d_head;
        let policy = || KvPolicy::DynamicQuant {
            tiers: vec![
                PageTier { pages: 2, dtype: Dtype::Bf16 },
                PageTier { pages: 6, dtype: Dtype::Fp8E4M3 },
            ],
        };
        let mk_kv = |seed: u64| -> KvState {
            let mut rng = Xoshiro256::new(seed);
            let scales: Vec<f32> = (0..row).map(|_| 2f32.powf(rng.normal() as f32)).collect();
            let mut kv = KvState {
                k: vec![0.0; meta.layers * meta.max_seq * row],
                v: vec![0.0; meta.layers * meta.max_seq * row],
                queries: vec![0.0; meta.layers * meta.n_heads * meta.d_head],
                pos: prefill,
            };
            for (i, x) in kv.k.iter_mut().enumerate() {
                *x = scales[i % row] * (1.0 + 0.05 * rng.normal() as f32);
            }
            for (i, x) in kv.v.iter_mut().enumerate() {
                *x = scales[i % row] * (1.0 + 0.05 * rng.normal() as f32);
            }
            for q in kv.queries.iter_mut() {
                *q = rng.normal() as f32;
            }
            kv
        };
        let run_serve = |batched: bool| -> f64 {
            let lanes = Arc::new(LaneArray::with_default_lanes());
            let mut kvs: Vec<KvState> = (1..=nseq as u64).map(mk_kv).collect();
            let mut stores: Vec<KvPageStore> = (0..nseq)
                .map(|_| {
                    KvPageStore::with_shared(
                        &meta,
                        Layout::Proposed,
                        Codec::Zstd,
                        Arc::clone(&lanes),
                    )
                })
                .collect();
            let engines: Vec<PolicyEngine> = (0..nseq)
                .map(|_| PolicyEngine::with_shared(policy(), Arc::clone(&lanes)))
                .collect();
            let t0 = Instant::now();
            for _step in 0..steps {
                for (kv, eng) in kvs.iter_mut().zip(&engines) {
                    kv.pos += 1; // stand-in for the model decode step
                    let plan = eng.plan(kv, &meta);
                    std::hint::black_box(plan.page_bits);
                }
                if batched {
                    let mut seqs: Vec<(&mut KvPageStore, &KvState)> =
                        stores.iter_mut().zip(kvs.iter()).collect();
                    sync_sequences(&mut seqs, &meta, &lanes);
                } else {
                    for (store, kv) in stores.iter_mut().zip(kvs.iter()) {
                        store.sync(kv, &meta);
                    }
                }
            }
            t0.elapsed().as_secs_f64()
        };
        // raw KV bytes synced over the run: every page stored by the end,
        // including the prefill backlog the first sync drains
        let page_raw = meta.layers * meta.page_tokens * row * 2 * 2;
        let serve_bytes = (nseq * ((prefill + steps) / meta.page_tokens) * page_raw) as f64;
        let tb = run_serve(true);
        b.row(
            "serve-shaped step loop batched sync (8 seq)",
            format!("{steps} steps"),
            tb,
            serve_bytes,
        );
        let tp = run_serve(false);
        b.row(
            "serve-shaped step loop per-seq sync (8 seq)",
            format!("{steps} steps"),
            tp,
            serve_bytes,
        );
        println!(
            "serve-shaped: batched sync {:.2}x per-seq ({:.1} vs {:.1} steps/s)",
            tp / tb,
            steps as f64 / tb,
            steps as f64 / tp
        );

        // ---- per-step plan: lazy views vs materialized copies ----
        // 8 full-context sequences under the pressure clamp: the lazy
        // KvViewPlan (O(pages), allocation-free via plan_pressured_into)
        // vs the copy plan (full degraded K/V clones + truncation sweep).
        // CI gates view >= copy via --check — the tentpole win.
        {
            let engines: Vec<PolicyEngine> = (0..nseq)
                .map(|_| PolicyEngine::with_lanes(policy(), 1))
                .collect();
            let kvs: Vec<KvState> = (1..=nseq as u64)
                .map(|s| {
                    let mut kv = mk_kv(s);
                    kv.pos = meta.max_seq;
                    kv
                })
                .collect();
            // bytes the plan describes (the degraded read surface): the
            // same unit for both rows so the ratio is the story
            let plan_bytes = (nseq * meta.layers * meta.max_seq * row * 2 * 4) as f64;
            let iters = if fast { 16 } else { 48 };
            let mut plans: Vec<KvViewPlan> = (0..nseq).map(|_| KvViewPlan::new()).collect();
            let tv = time(
                || {
                    for ((eng, kv), plan) in engines.iter().zip(&kvs).zip(plans.iter_mut()) {
                        eng.plan_pressured_into(kv, &meta, Some(8), plan);
                        std::hint::black_box(&plan.page_bits);
                    }
                },
                iters,
            );
            b.row(
                "view plan 8 seq (pressured)",
                humanfmt::bytes(plan_bytes as u64),
                tv,
                plan_bytes,
            );
            let tc = time(
                || {
                    for (eng, kv) in engines.iter().zip(&kvs) {
                        let p = eng.plan_materialized_pressured(kv, &meta, Some(8));
                        std::hint::black_box(&p.degraded_k);
                    }
                },
                if fast { 4 } else { 12 },
            );
            b.row(
                "copy plan 8 seq (pressured)",
                humanfmt::bytes(plan_bytes as u64),
                tc,
                plan_bytes,
            );
            println!("plan path: lazy views {:.2}x copy plan", tc / tv);
            if check {
                let mut measure = || {
                    let t_v = time(
                        || {
                            for ((eng, kv), plan) in
                                engines.iter().zip(&kvs).zip(plans.iter_mut())
                            {
                                eng.plan_pressured_into(kv, &meta, Some(8), plan);
                                std::hint::black_box(&plan.page_bits);
                            }
                        },
                        iters,
                    );
                    let t_c = time(
                        || {
                            for (eng, kv) in engines.iter().zip(&kvs) {
                                let p = eng.plan_materialized_pressured(kv, &meta, Some(8));
                                std::hint::black_box(&p.degraded_k);
                            }
                        },
                        if fast { 4 } else { 12 },
                    );
                    t_c / t_v
                };
                let mut ratio = measure();
                for _ in 0..2 {
                    if ratio >= 0.90 {
                        break;
                    }
                    ratio = ratio.max(measure());
                }
                if ratio < 0.90 {
                    eprintln!("gate: view plan {ratio:.2}x copy plan after retries");
                    plan_ok = false;
                }
            }
        }

        // ---- decode-side fetch dispatch: batched vs per-sequence vs ----
        // ---- per-page-Vec allocation ----
        // 8 full-context sequences, every stored page read at an 8-plane
        // prefix (the pressure-ladder shape): ONE cross-sequence lane
        // dispatch into the reusable step arena, vs one arena-backed load
        // per page, vs the pre-refactor shape (one fresh Vec per page
        // through MemController::load). CI gates batched >= per-seq AND
        // arena >= per-page-Vec via --check.
        {
            let lanes = Arc::new(LaneArray::with_default_lanes());
            let mut stores: Vec<KvPageStore> = (1..=nseq as u64)
                .map(|s| {
                    let mut kv = mk_kv(s);
                    kv.pos = meta.max_seq; // full context: 16 pages
                    let mut st = KvPageStore::with_shared(
                        &meta,
                        Layout::Proposed,
                        Codec::Zstd,
                        Arc::clone(&lanes),
                    );
                    st.sync(&kv, &meta);
                    st
                })
                .collect();
            let bits: Vec<Vec<u32>> = stores.iter().map(|s| vec![8u32; s.len()]).collect();
            let iters = if fast { 8 } else { 24 };
            let mut arena = DecodeArena::new();
            let fetch_bytes: f64 = {
                arena.reset();
                let mut seqs: Vec<(&mut KvPageStore, &[u32])> = stores
                    .iter_mut()
                    .zip(bits.iter())
                    .map(|(s, bb)| (s, bb.as_slice()))
                    .collect();
                let outs = fetch_sequences(&mut seqs, &lanes, &mut arena).unwrap();
                outs.iter().map(|o| o.dram_bytes_total()).sum::<u64>() as f64
            };
            let tb = time(
                || {
                    arena.reset();
                    let mut seqs: Vec<(&mut KvPageStore, &[u32])> = stores
                        .iter_mut()
                        .zip(bits.iter())
                        .map(|(s, bb)| (s, bb.as_slice()))
                        .collect();
                    std::hint::black_box(fetch_sequences(&mut seqs, &lanes, &mut arena).unwrap());
                },
                iters,
            );
            b.row(
                "batched fetch 8 seq (8 planes)",
                humanfmt::bytes(fetch_bytes as u64),
                tb,
                fetch_bytes,
            );
            let tp = time(
                || {
                    arena.reset();
                    for (s, bb) in stores.iter_mut().zip(bits.iter()) {
                        std::hint::black_box(s.fetch_pages(bb, &mut arena).unwrap());
                    }
                },
                iters,
            );
            b.row(
                "per-seq fetch 8 seq (8 planes)",
                humanfmt::bytes(fetch_bytes as u64),
                tp,
                fetch_bytes,
            );
            // the pre-refactor read shape: one fresh Vec<u16> per page
            let tvec = time(
                || {
                    for s in stores.iter_mut() {
                        for p in 0..s.len() {
                            std::hint::black_box(s.load_page_at(p, 8).unwrap());
                        }
                    }
                },
                iters,
            );
            b.row(
                "per-page-Vec fetch 8 seq (8 planes)",
                humanfmt::bytes(fetch_bytes as u64),
                tvec,
                fetch_bytes,
            );
            println!(
                "decode fetch: batched {:.2}x per-seq dispatch, arena {:.2}x per-page Vec",
                tp / tb,
                tvec / tb
            );
            if check {
                // same retry discipline as the pooled-dispatch gate: only
                // a consistently-slower batched fetch (a real regression)
                // fails all three attempts
                let mut measure = || {
                    let t_b = time(
                        || {
                            arena.reset();
                            let mut seqs: Vec<(&mut KvPageStore, &[u32])> = stores
                                .iter_mut()
                                .zip(bits.iter())
                                .map(|(s, bb)| (s, bb.as_slice()))
                                .collect();
                            std::hint::black_box(
                                fetch_sequences(&mut seqs, &lanes, &mut arena).unwrap(),
                            );
                        },
                        iters,
                    );
                    let t_p = time(
                        || {
                            arena.reset();
                            for (s, bb) in stores.iter_mut().zip(bits.iter()) {
                                std::hint::black_box(s.fetch_pages(bb, &mut arena).unwrap());
                            }
                        },
                        iters,
                    );
                    let t_vec = time(
                        || {
                            for s in stores.iter_mut() {
                                for p in 0..s.len() {
                                    std::hint::black_box(s.load_page_at(p, 8).unwrap());
                                }
                            }
                        },
                        iters,
                    );
                    (t_p / t_b, t_vec / t_b)
                };
                let (mut r_seq, mut r_vec) = measure();
                for _ in 0..2 {
                    if r_seq >= 0.90 && r_vec >= 0.90 {
                        break;
                    }
                    let (a, v) = measure();
                    r_seq = r_seq.max(a);
                    r_vec = r_vec.max(v);
                }
                if r_seq < 0.90 {
                    eprintln!("gate: batched fetch {r_seq:.2}x per-seq after retries");
                    fetch_ok = false;
                }
                if r_vec < 0.90 {
                    eprintln!("gate: arena fetch {r_vec:.2}x per-page-Vec after retries");
                    fetch_ok = false;
                }
            }
        }
    }

    // ---- DRAM sim command rate ----
    let mut mem = MemorySystem::new(DDR5_4800_PAPER.clone());
    let t0 = Instant::now();
    let sim_bytes = if fast { 4u64 << 20 } else { 32u64 << 20 };
    let cycles = mem.run_stream_read(0, sim_bytes);
    let wall = t0.elapsed().as_secs_f64();
    b.tab.row(&[
        "dram sim (streaming)".into(),
        format!("{cycles} cyc"),
        humanfmt::nanos(wall * 1e9),
        format!("{:.1} Mcyc/s", cycles as f64 / wall / 1e6),
    ]);
    b.report.insert(
        "dram_sim_streaming_cycles_per_sec",
        (cycles as f64 / wall).round(),
    );

    // ---- sharded DRAM channel overlap ----
    // the same volume split across 4 single-channel shards by
    // sequence-id hash: the channels drain concurrently, so the system
    // finishes at the slowest shard — the cycle-level witness behind the
    // serve path's channel_overlapped_ns model
    let mut sharded = ShardedMemSystem::new(DDR5_4800_PAPER.clone(), 4);
    let per_seq = sim_bytes / 8;
    let mut tag = 0;
    for id in 0..8u64 {
        tag = sharded.enqueue_range_for(id, id * (1 << 24), per_seq, false, tag);
    }
    let (overlapped, serial) = sharded.drain_overlapped();
    let overlap_x = serial as f64 / overlapped.max(1) as f64;
    b.tab.row(&[
        "dram sharded (4ch, hash-routed)".into(),
        format!("{overlapped} cyc"),
        format!("serial {serial} cyc"),
        format!("{overlap_x:.2}x overlap"),
    ]);
    b.report.insert(
        "dram_sharded_4ch_overlap_x",
        (overlap_x * 100.0).round() / 100.0,
    );

    b.tab.print();

    // lane-scaling summary (the acceptance metric: >=2x at 8 lanes)
    let serial_rate = batch_bytes / serial_seed;
    println!("\n== lane scaling (batched zstd compress, vs serial seed-style) ==");
    for &(lanes, rate) in &lane_rates {
        println!(
            "  {lanes:>2} lanes: {}  ({:.2}x serial)",
            humanfmt::rate(rate),
            rate / serial_rate
        );
    }

    // small-batch dispatch summary (the acceptance metric: pooled >=
    // 1.3x spawn/join at <=8 blocks, never slower than serial)
    println!("\n== small-batch dispatch (8 lanes, zstd, vs serial / spawn-join) ==");
    for &(nb, serial, pooled, spawnjoin) in &small_rows {
        println!(
            "  {nb} blk: pooled {}  ({:.2}x serial, {:.2}x spawn-join)",
            humanfmt::rate(pooled),
            pooled / serial,
            pooled / spawnjoin
        );
    }

    println!();
    b.report.write("BENCH_hotpath.json");

    if check && !pooled_ok {
        eprintln!("CHECK FAILED: pooled small-batch dispatch is slower than serial");
        std::process::exit(1);
    }
    if check && !fetch_ok {
        eprintln!(
            "CHECK FAILED: batched cross-sequence fetch is slower than per-sequence \
             (or the arena fetch lost to the per-page-Vec shape)"
        );
        std::process::exit(1);
    }
    if check && !plan_ok {
        eprintln!("CHECK FAILED: lazy view plan is slower than the materializing copy plan");
        std::process::exit(1);
    }
}
