//! Table III: bit-plane lossless compression ratios + total savings when
//! composed with lossy quantization, for four models x {BF16, FP8, INT4}.
//!
//!     cargo bench --bench table3_weight_compression

use camc::bitplane::plane_major_ratio;
use camc::compress::Codec;
use camc::configs::SWEEP_MODELS;
use camc::fmt::Dtype;
use camc::report::Table;
use camc::synth::{encode_checkpoint, sample_checkpoint};

fn main() {
    let mut tab = Table::new(
        "Table III: bit-plane ZSTD (4 KB) lossless ratio + total savings",
        &["model", "precision", "comp ratio", "lossless savings", "total savings"],
    );
    for cfg in SWEEP_MODELS {
        let ts = sample_checkpoint(cfg, 1 << 18, 42);
        for (dtype, lossy) in [
            (Dtype::Bf16, 0.0f64),
            (Dtype::Fp8E4M3, 0.5),
            (Dtype::Int4, 0.75),
        ] {
            let t = encode_checkpoint(&ts, dtype);
            let r = plane_major_ratio(dtype, &t.codes, Codec::Zstd, 4096);
            let lossless = (1.0 - 1.0 / r).max(0.0);
            let total = lossy + (1.0 - lossy) * lossless;
            tab.row(&[
                cfg.name.into(),
                dtype.to_string(),
                format!("{r:.2}"),
                format!("{:.1}%", lossless * 100.0),
                format!("{:.1}%", total * 100.0),
            ]);
        }
    }
    tab.print();
    println!(
        "paper: BF16 ratio 1.32-1.34 (24.4-25.6%), FP8 1.09-1.11 (8.0-9.9%,\n\
         total ~54%), INT4 1.01-1.02 (0.9-2.1%, total ~75%)."
    );
}
