//! Fig 11: average model load latency, P vs T, for 12 (model, base)
//! configs under dynamic quantization on DDR5-4800.
//!
//! The full per-token weight working set is simulated at a sampled scale
//! and scaled analytically to the model's true active-parameter count
//! (latency of a streaming load is linear in bytes at fixed efficiency —
//! the sim measures the efficiency, the scale-up is exact arithmetic).
//!
//!     cargo bench --bench fig11_load_latency

use camc::compress::Codec;
use camc::configs::ddr5::DDR5_4800_PAPER;
use camc::configs::SWEEP_MODELS;
use camc::dram::MemorySystem;
use camc::fmt::Dtype;
use camc::quant::mode::RouterSim;
use camc::quant::traffic::WeightTraffic;
use camc::report::Table;
use camc::synth::{encode_checkpoint, sample_checkpoint};

const SAMPLE_BYTES: u64 = 64 << 20;

fn load_ms(total_bits: f64) -> f64 {
    let mut mem = MemorySystem::new(DDR5_4800_PAPER.clone());
    let cycles = mem.run_stream_read(0, SAMPLE_BYTES);
    let secs = cycles as f64 * mem.cfg.t_ck();
    let bw = SAMPLE_BYTES as f64 / secs; // measured effective bandwidth
    total_bits / 8.0 / bw * 1e3
}

fn main() {
    let mut tab = Table::new(
        "Fig 11 — model load latency (active params), P vs T",
        &["model", "base", "P ms", "T ms", "savings"],
    );
    for cfg in SWEEP_MODELS {
        for base in [Dtype::Bf16, Dtype::Fp8E4M3, Dtype::Int4] {
            let ts = sample_checkpoint(cfg, 1 << 17, 42);
            let t = encode_checkpoint(&ts, base);
            let tr = WeightTraffic::measure(base, &t.codes, Codec::Zstd);
            let dist = RouterSim::paper_default(cfg.name).simulate(base, 1200, 64, 7);
            let (pb, tb) = tr.avg_bits(&dist);
            let n = cfg.active_params_per_token() as f64;
            let p_ms = load_ms(n * pb);
            let t_ms = load_ms(n * tb);
            tab.row(&[
                cfg.name.into(),
                base.to_string(),
                format!("{p_ms:.1}"),
                format!("{t_ms:.1}"),
                format!("{:.1}%", (1.0 - p_ms / t_ms) * 100.0),
            ]);
        }
    }
    tab.print();
    println!(
        "paper: Mixtral BF16 705.90 -> 495.06 ms (-30.0%); LLaMA-70B BF16\n\
         910.58 -> 674.73 ms (-25.9%); FP8/INT4 savings smaller.\n\
         shape: P < T everywhere; savings shrink with base precision;\n\
         latency ordered by active model size."
    );
}
